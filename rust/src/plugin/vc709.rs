//! The VC709 device plugin: consumes a deferred task subgraph, programs
//! the cluster through CONF registers, and executes the pass schedule.
//!
//! Execution is two synchronized views of the same byte flow:
//!
//! * **functional** — the grid really moves: DMA h2c -> A-SWT (routes
//!   decoded from the registers this plugin wrote) -> IPs (numerics via
//!   the configured backend) -> MFH MAC frames (CRC'd) -> NET fibers ->
//!   ... -> back to the host.  A mis-programmed route or MAC is an error
//!   or wrong numerics, never silently absorbed.
//! * **virtual time** — every hop is a [`crate::sim::Server`]; passes are
//!   streamed chunk-wise through the same hop sequence, yielding the
//!   virtual seconds that Figures 6-9 are built from.

use anyhow::{bail, Context, Result};

use std::collections::BTreeSet;

use super::backend::{ExecBackend, GoldenExec, PjrtExec, TimingOnlyExec};
use super::datamap;
use super::mapper::{self, Assignment, IpSlot};
use crate::config::{ClusterConfig, TimingConfig};
use crate::hw::axis::{ip_port, Burst, PORT_DMA, PORT_NET, PORT_VFIFO};
use crate::hw::board::Cluster;
use crate::hw::ip_core::{IpCore, StepExecutor};
use crate::hw::mac::{
    frame_cell_counts, MacAddr, MacFrame, ETHERTYPE_STENCIL, FCS_BYTES,
    HEADER_BYTES,
};
use crate::hw::net::{CHANNEL_EAST, CHANNEL_WEST};
use crate::hw::topology::{FabricSlot, Topology};
use crate::omp::dataenv::{BatchCtx, Residency};
use crate::omp::device::{
    BandSweep, DataEnv, DevicePlugin, DeviceReport, FnRegistry, HaloOp,
};
use crate::omp::graph::TaskGraph;
use crate::omp::task::TaskId;
use crate::sim::stats::RunStats;
use crate::sim::Server;
use crate::stencil::{Grid, Kernel};

/// MAC frame wire overhead relative to payload (26 B per 8 KiB frame).
const FRAME_OVERHEAD: f64 = 1.0
    + (crate::hw::mac::HEADER_BYTES + crate::hw::mac::FCS_BYTES) as f64
        / crate::hw::mac::MAX_PAYLOAD as f64;

pub struct Vc709Plugin {
    pub cluster: Cluster,
    backend: Box<dyn StepExecutor>,
    backend_kind: ExecBackend,
    timing: TimingConfig,
    /// Fuse same-kernel IP chains on one board into one backend `step_k`
    /// call (numerics identical — tested).  §Perf A/B (DESIGN.md §6):
    /// in isolation the interpret-lowered chain4 artifact is ~35% slower
    /// than 4 cached single steps, but at system level fusing still wins
    /// by ~10% because it quarters the Grid<->Literal marshalling copies
    /// (16 MB per call on the paper grid).  Default **on**.
    pub fuse_chains: bool,
    /// Route functional streaming through the pre-zero-copy path:
    /// clone-per-step backend calls, a placeholder grid allocated per
    /// parked pass and a re-copying VFIFO drain.  Kept behind this flag
    /// (default **off**) as the A/B baseline `benches/perf.rs` measures
    /// and the differential property tests compare against — grids and
    /// schedules are bit-identical either way (DESIGN.md §7).
    pub naive_stream: bool,
    /// report of the last batch, for inspection
    pub last_assignment: Option<Assignment>,
    /// When set, the next non-empty `run_batch` fails **atomically** —
    /// before any pass programs CONF registers or streams a byte, so
    /// the data environment is exactly as the caller handed it — with
    /// a typed [`DeviceFailed`] carrying this cause at the batch's
    /// release instant.  This is the plugin-raised half of the fault
    /// plane (the schedule-armed half lives in `omp::fault`): it
    /// models a board dying on dispatch (link drop, CONF timeout) and
    /// the executor's recovery path downcasts it by type, not by
    /// message.  Consumed by the failure it triggers.
    pub fail_next_batch: Option<String>,
    /// Intra-cluster fabric: how this plugin's own boards are wired.
    /// Routes and prices every pass crossing (from the cluster config;
    /// `Ring` reproduces the paper's deployment exactly).
    pub topology: Topology,
    /// This device's slot in the *sharding* fabric — the inter-device
    /// network halo exchanges travel (DESIGN.md §11).  Defaults to the
    /// solo slot (every exchange local); `omp::shard` deployments set
    /// one slot per tile device.
    pub fabric: FabricSlot,
}

impl Vc709Plugin {
    pub fn new(config: &ClusterConfig, backend: ExecBackend) -> Result<Vc709Plugin> {
        let boards: Vec<Vec<Kernel>> = config
            .fpgas
            .iter()
            .map(|f| f.ips.iter().map(|ip| ip.kernel).collect())
            .collect();
        let mut cluster = Cluster {
            boards: boards
                .iter()
                .enumerate()
                .map(|(id, ks)| crate::hw::board::Fpga::new(id, ks))
                .collect(),
        };
        // sanity: CONF magic present on every board
        for b in &mut cluster.boards {
            b.conf.check_magic()?;
        }
        let exec: Box<dyn StepExecutor> = match backend {
            ExecBackend::Golden => Box::new(GoldenExec::default()),
            ExecBackend::TimingOnly => Box::new(TimingOnlyExec::default()),
            ExecBackend::Pjrt => {
                Box::new(PjrtExec::from_dir(&config.bitstream_dir)?)
            }
        };
        Ok(Vc709Plugin {
            cluster,
            backend: exec,
            backend_kind: backend,
            timing: config.timing.clone(),
            fuse_chains: true,
            naive_stream: false,
            last_assignment: None,
            fail_next_batch: None,
            topology: config.topology,
            fabric: FabricSlot::solo(),
        })
    }

    pub fn backend_kind(&self) -> ExecBackend {
        self.backend_kind
    }

    fn board_kernels(&self) -> Vec<Vec<Kernel>> {
        self.cluster
            .boards
            .iter()
            .map(|b| b.ips.iter().map(|ip| ip.kernel).collect())
            .collect()
    }

    // ---------------------------------------------------------------------
    // CONF programming (per pass)
    // ---------------------------------------------------------------------

    /// Program every board's registers for one pass and decode them.
    /// Returns the per-board groups of the pass.
    fn program_pass(
        &mut self,
        slots: &[IpSlot],
        first_pass: bool,
        final_pass: bool,
        kernels: &[Kernel],
    ) -> Result<Vec<(usize, Vec<usize>)>> {
        let groups = group_slots(slots);
        let nboards = self.cluster.nboards();
        let last_board = groups
            .last()
            .map(|g| g.0)
            .ok_or_else(|| anyhow::anyhow!(
                "pass has no IP slots to program — mapper produced an \
                 empty pass"
            ))?;

        for b in &mut self.cluster.boards {
            b.conf.clear_log();
        }
        // clear all previous routing (fresh register image per pass)
        for b in 0..nboards {
            let board = &mut self.cluster.boards[b];
            let nports = board.switch.nports() as u8;
            for p in 0..nports {
                board.conf.clear_route(p);
            }
        }

        for (gi, (b, ips)) in groups.iter().enumerate() {
            let entry = if *b == 0 {
                if first_pass {
                    PORT_DMA
                } else {
                    PORT_VFIFO
                }
            } else {
                PORT_NET
            };
            let board = &mut self.cluster.boards[*b];
            // entry -> first IP, IP -> IP chain
            board.conf.program_route(entry, ip_port(ips[0]));
            for w in ips.windows(2) {
                board.conf.program_route(ip_port(w[0]), ip_port(w[1]));
            }
            // exit route from the last IP of the group
            let last_ip = *ips.last().ok_or_else(|| anyhow::anyhow!(
                "board {b}: empty IP group in pass — mapper produced a \
                 group with no slots"
            ))?;
            let is_last_group = gi + 1 == groups.len();
            let exit = if !is_last_group {
                PORT_NET
            } else if *b == 0 {
                // pass begins and ends on board 0: internal loop or DMA
                if final_pass {
                    PORT_DMA
                } else {
                    PORT_VFIFO
                }
            } else {
                PORT_NET // wrap around the ring back to board 0
            };
            board.conf.program_route(ip_port(last_ip), exit);
            // enable the group's IPs
            for &i in ips {
                let kid = IpCore::kernel_id(board.ips[i].kernel);
                board.conf.program_ip(i as u8, kid, gi as u16);
            }
        }

        // board 0: where do returning ring frames go?
        if last_board != 0 {
            let b0 = &mut self.cluster.boards[0];
            b0.conf.program_route(
                PORT_NET,
                if final_pass { PORT_DMA } else { PORT_VFIFO },
            );
        }

        // MFH streams for every board crossing (dependence edges that span
        // boards: "MAC addresses are extracted from the dependencies in
        // the task graph")
        let payload_cells = self.timing.chunk_cells as u32;
        let mut stream: u16 = 0;
        for gi in 0..groups.len() {
            let (b, _) = groups[gi];
            let dst_board = if gi + 1 < groups.len() {
                groups[gi + 1].0
            } else if b != 0 {
                0 // wrap to board 0
            } else {
                continue; // ends on board 0: no crossing
            };
            let dst = crate::hw::mac::MacAddr::for_port(
                dst_board as u8,
                CHANNEL_WEST as u8,
            );
            let src = crate::hw::mac::MacAddr::for_port(b as u8, CHANNEL_EAST as u8);
            self.cluster.boards[b].conf.program_mfh_stream(
                stream,
                dst,
                src,
                ETHERTYPE_STENCIL,
                payload_cells,
            );
            stream += 1;
        }

        // decode registers into hardware state (the other side of the
        // CONF contract)
        for b in &mut self.cluster.boards {
            b.apply_conf()
                .with_context(|| format!("decoding CONF on board {}", b.id))?;
        }

        // cross-check: the synthesized kernel of every assigned IP matches
        // the task it will run
        let mut ti = 0usize;
        for (b, ips) in &groups {
            for &i in ips {
                let want = kernels[ti];
                let have = self.cluster.boards[*b].ips[i].kernel;
                if want != have {
                    bail!(
                        "mapper bug: task {ti} needs {} but board {b} IP {i} \
                         is {}",
                        want.name(),
                        have.name()
                    );
                }
                ti += 1;
            }
        }
        Ok(groups)
    }

    // ---------------------------------------------------------------------
    // Functional streaming (one pass)
    // ---------------------------------------------------------------------

    /// One pass, functionally and allocation-free: every burst consults
    /// the decoded switch routes; crossings really pack MAC frames;
    /// numerics run in place through the backend's `step_k_into` against
    /// the caller-owned `scratch`.  `grid` is `Some` when the stream
    /// enters from the host (first pass) and `None` when it enters from
    /// the VFIFO park; the return value is `Some` only when the final
    /// pass delivers the grid back to the host — a parked stream returns
    /// `None` instead of allocating a placeholder.  The cell buffer
    /// itself threads through every hop by move: `into_data` →
    /// bursts → `from_vec` are all zero-copy (DESIGN.md §7).
    fn stream_pass(
        &mut self,
        grid: Option<Grid>,
        scratch: &mut Grid,
        groups: &[(usize, Vec<usize>)],
        first_pass: bool,
        final_pass: bool,
        shape: &[usize],
    ) -> Result<Option<Grid>> {
        // host -> board 0 entry
        let mut data = if first_pass {
            let g = grid.ok_or_else(|| {
                anyhow::anyhow!("first pass entered without a host grid")
            })?;
            self.cluster.boards[0].dma.h2c(g.into_data())
        } else {
            // from the VFIFO loop: the previous pass parked it there as
            // one burst, whose buffer is taken back without re-copying
            let mut bursts = self.cluster.boards[0].vfifo.drain();
            let cells = match bursts.len() {
                0 => Vec::new(),
                1 => bursts.remove(0).cells,
                _ => {
                    let mut cells = Vec::with_capacity(
                        bursts.iter().map(|b| b.cells.len()).sum(),
                    );
                    for b in bursts {
                        cells.extend(b.cells);
                    }
                    cells
                }
            };
            if cells.is_empty() {
                bail!("VFIFO empty at pass start (routing bug)");
            }
            cells
        };

        let mut ingress = if first_pass { PORT_DMA } else { PORT_VFIFO };
        // MFH stream ids were assigned in crossing order by program_pass
        let mut crossing: u16 = 0;
        for (gi, (b, ips)) in groups.iter().enumerate() {
            if gi == 0 && *b != 0 {
                bail!("pass must start on board 0 (mapper bug)");
            }
            // traverse this board's IP chain, fusing same-kernel runs
            let mut fuse_run: Vec<usize> = Vec::new();
            let mut i_iter = ips.iter().peekable();
            while let Some(&i) = i_iter.next() {
                let burst =
                    Burst { cells: data, stream_id: crossing, last: true };
                let egress = self.cluster.boards[*b]
                    .switch
                    .forward(ingress, &burst)
                    .with_context(|| format!("board {b} ingress {ingress}"))?;
                if egress != ip_port(i) {
                    bail!(
                        "route mismatch on board {b}: ingress {ingress} -> \
                         egress {egress}, expected IP port {}",
                        ip_port(i)
                    );
                }
                data = burst.cells;
                fuse_run.push(i);
                ingress = ip_port(i);
                let next_same = i_iter.peek().is_some_and(|&&n| {
                    self.cluster.boards[*b].ips[n].kernel
                        == self.cluster.boards[*b].ips[i].kernel
                });
                if !(self.fuse_chains && next_same) {
                    let mut g = Grid::from_vec(shape, data)?;
                    let k = self.cluster.boards[*b].ips[fuse_run[0]].kernel;
                    for &fi in &fuse_run {
                        if !self.cluster.boards[*b].ips[fi].enabled {
                            bail!("board {b} IP {fi} not enabled (CONF bug)");
                        }
                        self.cluster.boards[*b].ips[fi].invocations += 1;
                        self.cluster.boards[*b].ips[fi].cells_processed +=
                            g.cells() as u64;
                    }
                    self.backend
                        .step_k_into(k, fuse_run.len(), &mut g, scratch)
                        .with_context(|| {
                            format!("executing {} on board {b}", k.name())
                        })?;
                    data = g.into_data();
                    fuse_run.clear();
                }
            }
            // leave this board: consult the exit route
            let burst = Burst { cells: data, stream_id: crossing, last: true };
            let egress =
                self.cluster.boards[*b].switch.forward(ingress, &burst)?;
            data = burst.cells;
            let is_last_group = gi + 1 == groups.len();
            match (is_last_group, egress) {
                (false, e) if e == PORT_NET => {
                    let dst_board = groups[gi + 1].0;
                    data = self.ship(*b, dst_board, crossing, data)?;
                    crossing += 1;
                    ingress = PORT_NET;
                }
                (true, e) if e == PORT_NET => {
                    // wrap the ring back to board 0
                    data = self.ship(*b, 0, crossing, data)?;
                    if final_pass {
                        data = self.cluster.boards[0].dma.c2h(data);
                    } else {
                        self.cluster.boards[0].vfifo.push(Burst {
                            cells: std::mem::take(&mut data),
                            stream_id: crossing,
                            last: true,
                        })?;
                    }
                }
                (true, e) if e == PORT_DMA => {
                    debug_assert!(final_pass && *b == 0);
                    data = self.cluster.boards[0].dma.c2h(data);
                }
                (true, e) if e == PORT_VFIFO => {
                    debug_assert!(!final_pass && *b == 0);
                    self.cluster.boards[0].vfifo.push(Burst {
                        cells: std::mem::take(&mut data),
                        stream_id: crossing,
                        last: true,
                    })?;
                }
                (last, e) => bail!(
                    "unexpected egress {e} leaving board {b} \
                     (last_group={last})"
                ),
            }
        }
        if final_pass {
            Ok(Some(Grid::from_vec(shape, data)?))
        } else {
            Ok(None)
        }
    }

    /// The pre-zero-copy pass implementation, kept verbatim behind
    /// [`Vc709Plugin::naive_stream`]: `step_k` clones per iteration, a
    /// parked pass hands a freshly allocated placeholder back to the
    /// caller, and the VFIFO drain re-copies the cells.  Bit-identical
    /// grids and schedules by construction (the timing plane is shared);
    /// only the host-side allocator traffic differs — which is exactly
    /// the A/B `benches/perf.rs` quantifies.
    fn stream_pass_naive(
        &mut self,
        grid: Grid,
        groups: &[(usize, Vec<usize>)],
        first_pass: bool,
        final_pass: bool,
        shape: &[usize],
    ) -> Result<Grid> {
        // host -> board 0 entry
        let mut data = if first_pass {
            self.cluster.boards[0].dma.h2c(grid.into_data())
        } else {
            // from the VFIFO loop: the previous pass parked it there
            let bursts = self.cluster.boards[0].vfifo.drain();
            let mut cells = Vec::new();
            for b in bursts {
                cells.extend(b.cells);
            }
            if cells.is_empty() {
                bail!("VFIFO empty at pass start (routing bug)");
            }
            cells
        };

        let mut ingress = if first_pass { PORT_DMA } else { PORT_VFIFO };
        let mut crossing: u16 = 0;
        for (gi, (b, ips)) in groups.iter().enumerate() {
            if gi == 0 && *b != 0 {
                bail!("pass must start on board 0 (mapper bug)");
            }
            let mut fuse_run: Vec<usize> = Vec::new();
            let mut i_iter = ips.iter().peekable();
            while let Some(&i) = i_iter.next() {
                let burst =
                    Burst { cells: data, stream_id: crossing, last: true };
                let egress = self.cluster.boards[*b]
                    .switch
                    .forward(ingress, &burst)
                    .with_context(|| format!("board {b} ingress {ingress}"))?;
                if egress != ip_port(i) {
                    bail!(
                        "route mismatch on board {b}: ingress {ingress} -> \
                         egress {egress}, expected IP port {}",
                        ip_port(i)
                    );
                }
                data = burst.cells;
                fuse_run.push(i);
                ingress = ip_port(i);
                let next_same = i_iter.peek().is_some_and(|&&n| {
                    self.cluster.boards[*b].ips[n].kernel
                        == self.cluster.boards[*b].ips[i].kernel
                });
                if !(self.fuse_chains && next_same) {
                    let g = Grid::from_vec(shape, data)?;
                    let k = self.cluster.boards[*b].ips[fuse_run[0]].kernel;
                    for &fi in &fuse_run {
                        if !self.cluster.boards[*b].ips[fi].enabled {
                            bail!("board {b} IP {fi} not enabled (CONF bug)");
                        }
                        self.cluster.boards[*b].ips[fi].invocations += 1;
                        self.cluster.boards[*b].ips[fi].cells_processed +=
                            g.cells() as u64;
                    }
                    let out = self
                        .backend
                        .step_k(k, &g, fuse_run.len())
                        .with_context(|| {
                            format!("executing {} on board {b}", k.name())
                        })?;
                    data = out.into_data();
                    fuse_run.clear();
                }
            }
            // leave this board: consult the exit route
            let burst = Burst { cells: data, stream_id: crossing, last: true };
            let egress =
                self.cluster.boards[*b].switch.forward(ingress, &burst)?;
            data = burst.cells;
            let is_last_group = gi + 1 == groups.len();
            match (is_last_group, egress) {
                (false, e) if e == PORT_NET => {
                    let dst_board = groups[gi + 1].0;
                    data = self.ship(*b, dst_board, crossing, data)?;
                    crossing += 1;
                    ingress = PORT_NET;
                }
                (true, e) if e == PORT_NET => {
                    data = self.ship(*b, 0, crossing, data)?;
                    if final_pass {
                        data = self.cluster.boards[0].dma.c2h(data);
                    } else {
                        self.cluster.boards[0].vfifo.push(Burst {
                            cells: std::mem::take(&mut data),
                            stream_id: crossing,
                            last: true,
                        })?;
                    }
                }
                (true, e) if e == PORT_DMA => {
                    debug_assert!(final_pass && *b == 0);
                    data = self.cluster.boards[0].dma.c2h(data);
                }
                (true, e) if e == PORT_VFIFO => {
                    debug_assert!(!final_pass && *b == 0);
                    self.cluster.boards[0].vfifo.push(Burst {
                        cells: std::mem::take(&mut data),
                        stream_id: crossing,
                        last: true,
                    })?;
                }
                (last, e) => bail!(
                    "unexpected egress {e} leaving board {b} \
                     (last_group={last})"
                ),
            }
        }
        if final_pass {
            Grid::from_vec(shape, data)
        } else {
            Grid::zeros(shape)
        }
    }

    /// MFH-pack `cells` on `from`, push frames link-by-link along the
    /// topology's routed path (intermediate boards forward by MAC
    /// compare, no unpack) until `to`, unpack.  On the default `Ring`
    /// this is exactly the historical eastward walk; a `Crossbar`
    /// circuit delivers in one hop, a `Torus` walks row-then-column.
    fn ship(
        &mut self,
        from: usize,
        to: usize,
        stream: u16,
        cells: Vec<f32>,
    ) -> Result<Vec<f32>> {
        let n = self.cluster.nboards();
        if n < 2 {
            bail!("fabric shipment on a single-board cluster");
        }
        let path = self.topology.path(n, from, to);
        let burst = Burst { cells, stream_id: stream, last: true };
        let frames = self.cluster.boards[from].mfh.pack(&burst)?;
        for f in frames {
            self.cluster.boards[from].net.send(CHANNEL_EAST, &f)?;
        }
        for (i, &tx) in path.iter().enumerate() {
            let next = path.get(i + 1).copied().unwrap_or(to);
            self.cluster.propagate_pair(tx, next)?;
            if next == to {
                continue;
            }
            // intermediate board: forward every frame whose dst is not
            // local (MAC-compare forwarding; no unpack)
            let local = self.cluster.boards[next].mac(CHANNEL_WEST as u8);
            loop {
                let f = match self.cluster.boards[next].net.recv(CHANNEL_WEST)? {
                    None => break,
                    Some(f) => f,
                };
                if f.dst == local {
                    bail!(
                        "frame for board {to} terminated early at board {next}"
                    );
                }
                self.cluster.boards[next].net.send(CHANNEL_EAST, &f)?;
            }
        }
        let out = self.cluster.drain_rx(to)?;
        if out.is_empty() {
            bail!("no cells arrived at board {to} (fabric routing bug)");
        }
        Ok(out)
    }

    // ---------------------------------------------------------------------
    // Halo exchange (sharded grids; DESIGN.md §11)
    // ---------------------------------------------------------------------

    /// Functionally execute one halo exchange: read the source rows from
    /// the shared environment, carry them as CRC'd MAC frames across the
    /// sharding fabric (frame-for-frame — segmentation, addressing, FCS
    /// and sequence order all checked, exactly like a stream crossing),
    /// and write them into the destination tile.  Returns the total
    /// functional wire bytes (every frame counted once per link hop);
    /// a same-slot exchange moves on-chip and puts zero bytes on the
    /// wire.
    fn exchange_halo(&mut self, env: &mut DataEnv, op: &HaloOp) -> Result<f64> {
        let cells = {
            let src = env.get(&op.src)?;
            op.read_src(src)?
        };
        let hops = self
            .fabric
            .topology
            .hops(self.fabric.nboards, op.src_slot, op.dst_slot);
        let mut wire_total = 0usize;
        let cells = if hops == 0 {
            cells
        } else {
            let src_mac =
                MacAddr::for_port(op.src_slot as u8, CHANNEL_EAST as u8);
            let dst_mac =
                MacAddr::for_port(op.dst_slot as u8, CHANNEL_WEST as u8);
            let mut out = Vec::with_capacity(cells.len());
            let mut off = 0usize;
            for (seq, count) in
                frame_cell_counts(cells.len()).into_iter().enumerate()
            {
                let frame = MacFrame {
                    dst: dst_mac,
                    src: src_mac,
                    ethertype: ETHERTYPE_STENCIL,
                    stream_id: 0,
                    seq: seq as u32,
                    payload: crate::hw::mac::cells_to_bytes(
                        &cells[off..off + count],
                    ),
                };
                off += count;
                let bytes = frame.pack();
                // the same frame traverses every link on the path;
                // intermediate slots forward by MAC compare (no unpack)
                wire_total += bytes.len() * hops;
                let got = MacFrame::unpack(&bytes)?;
                if got.dst != dst_mac || got.ethertype != ETHERTYPE_STENCIL {
                    bail!(
                        "halo frame misaddressed: dst {} (expected {})",
                        got.dst,
                        dst_mac
                    );
                }
                if got.seq != seq as u32 {
                    bail!(
                        "halo frame out of order: seq {} (expected {seq})",
                        got.seq
                    );
                }
                out.extend(crate::hw::mac::bytes_to_cells(&got.payload)?);
            }
            out
        };
        let mut dst = env.take(&op.dst)?;
        let res = op.write_dst(&mut dst, &cells);
        env.put(&op.dst, dst);
        res?;
        Ok(wire_total as f64)
    }

    /// DES pricing of one halo exchange, frame-for-frame over the same
    /// [`frame_cell_counts`] segmentation the functional path ships:
    /// each frame's full wire bytes occupy every fabric link on the
    /// routed `src_slot -> dst_slot` path in store-and-forward order,
    /// then the destination board's switch delivers it.  The single
    /// timing path behind both `run_batch` and `estimate_batch_s`, so
    /// estimate == executed duration extends to halo traffic, and the
    /// bytes the halo servers record equal the functional wire bytes
    /// exactly.
    fn model_halo(
        &self,
        servers: &mut DesServers,
        op: &HaloOp,
        start_s: f64,
    ) -> f64 {
        let path = self
            .fabric
            .topology
            .path(self.fabric.nboards, op.src_slot, op.dst_slot);
        if path.is_empty() {
            // same-slot exchange: one on-chip switch traversal
            return servers.switch[0].offer(start_s, op.cells() as f64 * 4.0);
        }
        let mut finish = start_s;
        for count in frame_cell_counts(op.cells()) {
            let wire = (count * 4 + HEADER_BYTES + FCS_BYTES) as f64;
            let mut t = start_s;
            for &tx in &path {
                t = servers.halo[tx].offer(t, wire);
            }
            t = servers.switch[0].offer(t, wire);
            finish = finish.max(t);
        }
        finish
    }

    /// Execute one band-restricted sweep (interior/boundary split
    /// schedules, DESIGN.md §12): the band's sub-grid — its rows plus
    /// the one-row fringe — is extracted from the previous-parity tile
    /// buffer, streamed through this cluster exactly like a
    /// whole-buffer segment (same CONF programming, same backend
    /// numerics, so the swept rows are bit-identical to the host
    /// row-band path), and the interior rows are written back into the
    /// band of the destination parity buffer.
    fn run_band(&mut self, env: &mut DataEnv, band: &BandSweep) -> Result<()> {
        let assignment =
            mapper::assign(&self.board_kernels(), &[band.kernel])?;
        if assignment.npasses() != 1 {
            bail!(
                "band sweep on '{}': single kernel mapped to {} passes",
                band.dst,
                assignment.npasses()
            );
        }
        let shape = band.sub_shape();
        let groups = self.program_pass(
            &assignment.pass_slots(0),
            true,
            true,
            &[band.kernel],
        )?;
        if self.backend_kind != ExecBackend::TimingOnly {
            let sub = {
                let src = env.get(&band.src)?;
                band.extract(src)?
            };
            let swept = if self.naive_stream {
                self.stream_pass_naive(sub, &groups, true, true, &shape)?
            } else {
                let mut scratch = if self.backend.uses_scratch() {
                    Grid::zeros(&shape)?
                } else {
                    Grid::zeros(&[1, 1])?
                };
                self.stream_pass(
                    Some(sub),
                    &mut scratch,
                    &groups,
                    true,
                    true,
                    &shape,
                )?
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "band sweep on '{}' ended parked on the device \
                         (routing bug)",
                        band.dst
                    )
                })?
            };
            let mut dst = env.take(&band.dst)?;
            let res = band.write_back(&mut dst, &swept);
            env.put(&band.dst, dst);
            res?;
        }
        self.last_assignment = Some(assignment);
        Ok(())
    }

    /// DES pricing of one band-restricted sweep: a synthetic
    /// single-pass [`SegPlan`] over the band's sub-grid geometry,
    /// priced by the exact [`Vc709Plugin::model_segments`] path whole
    /// buffers use.  Consults only geometry baked into the band (plus
    /// the caller's residency flags), never buffer values, so
    /// `estimate_batch_s` over a shape-only phantom environment prices
    /// identically to execution — estimate == executed duration extends
    /// to band traffic.  Errs when no IP in this cluster implements the
    /// band's kernel (placement abstains).
    fn model_band(
        &self,
        servers: &mut DesServers,
        band: &BandSweep,
        entry_resident: bool,
        exit_deferred: bool,
        start_s: f64,
    ) -> Result<f64> {
        let assignment =
            mapper::assign(&self.board_kernels(), &[band.kernel])?;
        let seg = SegPlan {
            buffer: band.dst.clone(),
            kernels: vec![band.kernel],
            assignment,
            shape: band.sub_shape(),
            bytes: band.sub_bytes(),
            entry_resident,
            exit_deferred,
        };
        Ok(self.model_segments(servers, std::slice::from_ref(&seg), start_s))
    }

    // ---------------------------------------------------------------------
    // Virtual-time streaming (DES over the same hop sequence)
    // ---------------------------------------------------------------------

    fn build_servers(&self) -> DesServers {
        let t = &self.timing;
        let n = self.cluster.nboards();
        DesServers {
            pcie: Server::new("pcie", t.pcie_bps(), t.dma_setup_s),
            // write and read ports of the DDR3-backed VFIFO are separate
            // servers: DDR3 serves both concurrently (2 x 10 Gb/s effective
            // < 25.6 Gb/s raw), and a pass's exit must not block the next
            // chunk's entry
            vfifo_in: (0..n)
                .map(|_| Server::new("vfifo-w", t.vfifo_bps, t.vfifo_latency_s))
                .collect(),
            vfifo_out: (0..n)
                .map(|_| Server::new("vfifo-r", t.vfifo_bps, t.vfifo_latency_s))
                .collect(),
            net: (0..n)
                .map(|_| Server::new("net", t.net_bps, t.net_latency_s))
                .collect(),
            switch: (0..n)
                .map(|_| Server::latency_only("switch", t.switch_latency_s))
                .collect(),
            ips: self
                .cluster
                .boards
                .iter()
                .map(|b| {
                    b.ips
                        .iter()
                        .map(|_| Server::new("ip", t.ip_bps(), 0.0))
                        .collect()
                })
                .collect(),
            // one store-and-forward server per transmitting slot of the
            // sharding fabric — halo frames occupy every link on their
            // routed path (same bandwidth/latency class as the intra-
            // cluster fibers, but accounted as its own module so halo
            // traffic is visible in the run stats)
            halo: (0..self.fabric.nboards)
                .map(|_| Server::new("halo-net", t.net_bps, t.net_latency_s))
                .collect(),
        }
    }

    /// Hop sequence of one pass, as (server kind, board, ip) references.
    /// `entry` is the pass's ingress hop (PCIe DMA for a fresh stream,
    /// the board-0 VFIFO read port for a loop-back or a device-resident
    /// buffer); `exit` its egress hop, or `None` when the stream parks on
    /// the device (deferred D2H — the data simply stays where the last
    /// hop deposited it, which is what makes residency free at the tail).
    fn pass_hops(
        &self,
        groups: &[(usize, Vec<usize>)],
        entry: Hop,
        exit: Option<Hop>,
        shape: &[usize],
    ) -> Vec<Hop> {
        let mut hops = vec![entry];
        for (gi, (b, ips)) in groups.iter().enumerate() {
            hops.push(Hop::Switch(*b));
            for &i in ips {
                hops.push(Hop::Ip(*b, i, self.timing.ip_fill_s(shape)));
            }
            let is_last = gi + 1 == groups.len();
            let dst = if !is_last {
                Some(groups[gi + 1].0)
            } else if *b != 0 {
                Some(0)
            } else {
                None
            };
            if let Some(d) = dst {
                // one Net hop per transmitting board on the topology's
                // routed path — the same path `ship` walks functionally
                for tx in self.topology.path(self.cluster.nboards(), *b, d) {
                    hops.push(Hop::Net(tx));
                }
            }
        }
        hops.extend(exit);
        hops
    }

    /// Resolve a batch into per-segment execution plans: one maximal
    /// same-buffer sub-chain at a time, each with its own mapper
    /// assignment, grid shape and transfer decisions.  A segment enters
    /// from the device park (VFIFO) instead of PCIe when its buffer's
    /// device copy is current — either resident via the present table
    /// (`residency.device_valid`) or parked by an earlier segment of this
    /// batch — and defers its D2H when the buffer stays on the device
    /// (resident, or reused by a later segment).  Shared verbatim by
    /// `run_batch` and `estimate_batch_s`, so the placement estimate and
    /// the executed duration cannot drift.
    fn plan_segments(
        &self,
        graph: &TaskGraph,
        tasks: &[TaskId],
        kernels: &[Kernel],
        env: &DataEnv,
        residency: &Residency,
    ) -> Result<Vec<SegPlan>> {
        let segs = datamap::segments(graph, tasks)?;
        self.segment_plans(&segs, kernels, env, residency)
    }

    /// [`Vc709Plugin::plan_segments`] over a precomputed segment split —
    /// `run_batch` analyzes the chain once via [`datamap::plan`] and
    /// feeds both views from that single walk.
    fn segment_plans(
        &self,
        segs: &[datamap::Segment],
        kernels: &[Kernel],
        env: &DataEnv,
        residency: &Residency,
    ) -> Result<Vec<SegPlan>> {
        let mut on_device: BTreeSet<String> = residency.device_valid.clone();
        let mut plans = Vec::with_capacity(segs.len());
        let mut cursor = 0usize; // segments partition `tasks` in order
        for (si, seg) in segs.iter().enumerate() {
            let idxs: Vec<usize> = (cursor..cursor + seg.tasks.len()).collect();
            cursor += seg.tasks.len();
            let seg_kernels: Vec<Kernel> =
                idxs.iter().map(|&i| kernels[i]).collect();
            let assignment =
                mapper::assign(&self.board_kernels(), &seg_kernels)?;
            let (bytes, shape) = match env.get(&seg.buffer) {
                Ok(g) => (g.bytes() as f64, g.shape().to_vec()),
                Err(_) => (0.0, vec![1, 1]),
            };
            if bytes > 0.0 {
                for k in &seg_kernels {
                    if k.ndim() != shape.len() {
                        bail!(
                            "kernel {} expects {}D but buffer '{}' is {}D",
                            k.name(),
                            k.ndim(),
                            seg.buffer,
                            shape.len()
                        );
                    }
                }
            }
            let entry_resident = on_device.contains(&seg.buffer);
            let exit_deferred = residency.resident.contains(&seg.buffer)
                || segs[si + 1..].iter().any(|s| s.buffer == seg.buffer);
            if exit_deferred {
                on_device.insert(seg.buffer.clone());
            } else {
                on_device.remove(&seg.buffer);
            }
            plans.push(SegPlan {
                buffer: seg.buffer.clone(),
                kernels: seg_kernels,
                assignment,
                shape,
                bytes,
                entry_resident,
                exit_deferred,
            });
        }
        Ok(plans)
    }

    /// The DES over a batch's segments: every pass of every segment
    /// streamed chunk-wise through its hop sequence, starting at
    /// `start_s`.  The single timing path behind both `run_batch` and
    /// `estimate_batch_s` — a segment whose buffer is device-resident
    /// enters through the VFIFO read port instead of the PCIe DMA, and a
    /// deferred D2H charges nothing (the stream tail rests on the
    /// device), so the model prices only the transfers that actually
    /// happen.
    fn model_segments(
        &self,
        servers: &mut DesServers,
        segs: &[SegPlan],
        start_s: f64,
    ) -> f64 {
        let mut vtime = start_s;
        for seg in segs {
            let npasses = seg.assignment.npasses();
            for p in 0..npasses {
                let groups = group_slots(&seg.assignment.pass_slots(p));
                let entry = if p > 0 || seg.entry_resident {
                    Hop::VfifoRead(0)
                } else {
                    Hop::Pcie
                };
                let exit = if p + 1 < npasses {
                    Some(Hop::VfifoWrite(0))
                } else if seg.exit_deferred {
                    None
                } else {
                    Some(Hop::Pcie)
                };
                let hops = self.pass_hops(&groups, entry, exit, &seg.shape);
                vtime += self.timing.pass_overhead_s;
                vtime = self.stream_pass_virtual(servers, &hops, vtime, seg.bytes);
            }
        }
        vtime
    }

    fn stream_pass_virtual(
        &self,
        servers: &mut DesServers,
        hops: &[Hop],
        start_s: f64,
        total_bytes: f64,
    ) -> f64 {
        let chunk = self.timing.chunk_bytes();
        let chunks = (total_bytes / chunk).ceil().max(1.0) as usize;
        let mut finish = start_s;
        let mut remaining = total_bytes;
        for _ in 0..chunks {
            let b = remaining.min(chunk);
            remaining -= b;
            let mut t = start_s;
            for hop in hops {
                t = match *hop {
                    Hop::Pcie => servers.pcie.offer(t, b),
                    Hop::VfifoWrite(bd) => servers.vfifo_in[bd].offer(t, b),
                    Hop::VfifoRead(bd) => servers.vfifo_out[bd].offer(t, b),
                    Hop::Switch(bd) => servers.switch[bd].offer(t, b),
                    Hop::Ip(bd, i, fill) => {
                        let s = &mut servers.ips[bd][i];
                        // fill latency applies once per pass; model as the
                        // server's latency component
                        s.latency_s = fill;
                        let done = s.offer(t, b);
                        s.latency_s = 0.0;
                        done
                    }
                    Hop::Net(bd) => {
                        servers.net[bd].offer(t, b * FRAME_OVERHEAD)
                    }
                };
            }
            finish = finish.max(t);
        }
        finish
    }
}

/// Group consecutive pass slots by board: one group = one contiguous IP
/// chain on a board between ring crossings.
fn group_slots(slots: &[IpSlot]) -> Vec<(usize, Vec<usize>)> {
    let mut groups: Vec<(usize, Vec<usize>)> = Vec::new();
    for s in slots {
        match groups.last_mut() {
            Some((b, v)) if *b == s.board => v.push(s.ip),
            _ => groups.push((s.board, vec![s.ip])),
        }
    }
    groups
}

#[derive(Debug, Clone, Copy)]
enum Hop {
    Pcie,
    VfifoWrite(usize),
    VfifoRead(usize),
    Switch(usize),
    Ip(usize, usize, f64),
    Net(usize),
}

/// Execution plan of one maximal same-buffer sub-chain of a batch.
struct SegPlan {
    buffer: String,
    /// kernels of the segment's tasks, in chain order
    kernels: Vec<Kernel>,
    assignment: Assignment,
    shape: Vec<usize>,
    bytes: f64,
    /// the device copy is current at entry: read the VFIFO park, skip
    /// the H2D DMA
    entry_resident: bool,
    /// the buffer stays on the device: defer (skip) the D2H
    exit_deferred: bool,
}

struct DesServers {
    pcie: Server,
    vfifo_in: Vec<Server>,
    vfifo_out: Vec<Server>,
    net: Vec<Server>,
    switch: Vec<Server>,
    ips: Vec<Vec<Server>>,
    /// sharding-fabric links (halo exchange), indexed by fabric slot
    halo: Vec<Server>,
}

impl DesServers {
    fn absorb_into(&self, stats: &mut RunStats) {
        stats.absorb_server(&self.pcie);
        for s in self
            .vfifo_in
            .iter()
            .chain(&self.vfifo_out)
            .chain(&self.net)
            .chain(&self.switch)
            .chain(&self.halo)
        {
            stats.absorb_server(s);
        }
        for b in &self.ips {
            for s in b {
                stats.absorb_server(s);
            }
        }
    }
}

impl DevicePlugin for Vc709Plugin {
    fn arch(&self) -> &'static str {
        "vc709"
    }

    fn describe(&self) -> String {
        format!(
            "VC709 Multi-FPGA {}: {} boards, {} IPs, backend {:?}",
            self.topology.name(),
            self.cluster.nboards(),
            self.cluster.total_ips(),
            self.backend_kind
        )
    }

    fn run_batch(
        &mut self,
        graph: &TaskGraph,
        tasks: &[TaskId],
        env: &mut DataEnv,
        fns: &FnRegistry,
        ctx: &BatchCtx,
    ) -> Result<DeviceReport> {
        let t0 = std::time::Instant::now();
        let release_s = ctx.release_s;
        if tasks.is_empty() {
            return Ok(DeviceReport {
                release_s,
                finish_s: release_s,
                ..DeviceReport::default()
            });
        }
        // injected board death: fail before touching CONF, the VFIFO or
        // the data environment, so recovery sees pre-dispatch state
        if let Some(cause) = self.fail_next_batch.take() {
            return Err(crate::omp::DeviceFailed {
                at_s: release_s,
                cause,
            }
            .into());
        }
        // -- validate the batch is a chain in the given order ------------
        for pair in tasks.windows(2) {
            let succ = graph.task(pair[1]);
            if !graph.preds(succ.id).contains(&pair[0]) && !graph.preds(succ.id).is_empty()
            {
                bail!(
                    "VC709 plugin supports pipeline chains; task {} does not \
                     follow {} in the dependence chain",
                    succ.id.0,
                    pair[0].0
                );
            }
        }
        // -- partition into kernel / halo / band sections (order-
        // preserving).  Halo-exchange and band-sweep tasks ride the
        // ordinary graph, so a condensed run may interleave whole-buffer
        // sweeps, exchanges and band sweeps.  Each maximal stretch of
        // one flavor is planned with its own machinery, but all sections
        // share one DES server set and one virtual-time cursor, so the
        // batch prices as a single timeline.
        enum Section {
            Kernels(Vec<TaskId>),
            Halos(Vec<TaskId>),
            Bands(Vec<TaskId>),
        }
        let mut sections: Vec<Section> = Vec::new();
        for &id in tasks {
            let name = &graph.task(id).fn_name;
            if fns.halo_of(name).is_some() {
                match sections.last_mut() {
                    Some(Section::Halos(v)) => v.push(id),
                    _ => sections.push(Section::Halos(vec![id])),
                }
            } else if fns.band_of(name).is_some() {
                match sections.last_mut() {
                    Some(Section::Bands(v)) => v.push(id),
                    _ => sections.push(Section::Bands(vec![id])),
                }
            } else {
                match sections.last_mut() {
                    Some(Section::Kernels(v)) => v.push(id),
                    _ => sections.push(Section::Kernels(vec![id])),
                }
            }
        }

        let mut servers = self.build_servers();
        // the batch DAG's release time positions this batch on the global
        // virtual timeline, then the one-time offload startup (graph
        // handoff + device init) applies per offload episode
        let mut vtime = release_s + self.timing.offload_startup_s;
        let mut total_passes = 0usize;
        let mut h2d_elided = 0usize;
        let mut d2h_deferred = 0usize;
        let mut roundtrips_elided = 0usize;
        let mut halo_wire = 0.0f64;
        let mut ran_halos = false;

        for section in &sections {
            let ids = match section {
                Section::Halos(ids) => {
                    for id in ids {
                        let op = fns
                            .halo_of(&graph.task(*id).fn_name)
                            .ok_or_else(|| {
                                anyhow::anyhow!(
                                    "task {} lost its halo op mid-batch",
                                    id.0
                                )
                            })?
                            .clone();
                        halo_wire += self.exchange_halo(env, &op)?;
                        vtime = self.model_halo(&mut servers, &op, vtime);
                        ran_halos = true;
                    }
                    continue;
                }
                Section::Bands(ids) => {
                    for id in ids {
                        let band = fns
                            .band_of(&graph.task(*id).fn_name)
                            .ok_or_else(|| {
                                anyhow::anyhow!(
                                    "task {} lost its band sweep mid-batch",
                                    id.0
                                )
                            })?
                            .clone();
                        // the streamed bytes originate in the source
                        // parity buffer and land in the destination one:
                        // H2D elides when the source's device copy is
                        // current, D2H defers while the destination
                        // stays resident — the same residency facts the
                        // estimate consults
                        let entry_resident =
                            ctx.residency.device_valid.contains(&band.src);
                        let exit_deferred =
                            ctx.residency.resident.contains(&band.dst);
                        self.run_band(env, &band)?;
                        vtime = self.model_band(
                            &mut servers,
                            &band,
                            entry_resident,
                            exit_deferred,
                            vtime,
                        )?;
                        total_passes += 1;
                        if entry_resident {
                            h2d_elided += 1;
                        }
                        if exit_deferred {
                            d2h_deferred += 1;
                        }
                    }
                    continue;
                }
                Section::Kernels(ids) => ids,
            };
            // -- resolve kernels ------------------------------------------
            let kernels: Vec<Kernel> = ids
                .iter()
                .map(|id| fns.kernel_of(&graph.task(*id).fn_name))
                .collect::<Result<_>>()?;
            // -- plan -----------------------------------------------------
            // one chain walk yields both views: the per-buffer coalescing
            // analysis (how many host round-trips the pipeline view
            // eliminates, reported through the run stats) and the segment
            // split the streaming + timing below consume
            let batch_plan = datamap::plan(graph, ids)?;
            let segs = self.segment_plans(
                &batch_plan.segments,
                &kernels,
                env,
                &ctx.residency,
            )?;

            // -- functional streaming, one segment at a time --------------
            // The grids really move regardless of residency: the host data
            // environment stays the functional truth, which is what makes
            // resident and always-stream executions bit-identical.  Skipped
            // entirely in timing-only mode (figure sweeps; numerics are
            // identity).  One caller-owned ping-pong pair serves the whole
            // segment: `grid` is `Some` while the stream is host-side
            // (before the first pass, after the final one) and `None` while
            // parked in the VFIFO between passes; `scratch` is the single
            // per-segment allocation the backend's in-place kernels swap
            // against.
            for seg in &segs {
                let mut grid = Some(env.take(&seg.buffer)?);
                let stream = self.backend_kind != ExecBackend::TimingOnly;
                // a backend that owns its outputs (PJRT) never touches the
                // ping-pong scratch, so it gets a 1-cell stub instead of a
                // dead full-grid allocation per segment
                let mut scratch = if stream && !self.naive_stream {
                    Some(if self.backend.uses_scratch() {
                        Grid::zeros(&seg.shape)?
                    } else {
                        Grid::zeros(&[1, 1])?
                    })
                } else {
                    None
                };
                let npasses = seg.assignment.npasses();
                for p in 0..npasses {
                    let slots = seg.assignment.pass_slots(p);
                    let pass_kernels: Vec<Kernel> = seg.assignment.passes[p]
                        .iter()
                        .map(|&t| seg.kernels[t])
                        .collect();
                    let first = p == 0;
                    let fin = p + 1 == npasses;
                    let groups =
                        self.program_pass(&slots, first, fin, &pass_kernels)?;
                    if !stream {
                        continue;
                    }
                    grid = match scratch.as_mut() {
                        Some(s) => self.stream_pass(
                            grid.take(),
                            s,
                            &groups,
                            first,
                            fin,
                            &seg.shape,
                        )?,
                        None => {
                            // pre-PR baseline (behind `naive_stream`): the
                            // placeholder a parked pass returns keeps the
                            // Option occupied, exactly as the old code
                            // flowed
                            let g = grid.take().ok_or_else(|| {
                                anyhow::anyhow!(
                                    "pass {p} of segment '{}' lost its grid",
                                    seg.buffer
                                )
                            })?;
                            Some(self.stream_pass_naive(
                                g, &groups, first, fin, &seg.shape,
                            )?)
                        }
                    };
                }
                let grid = grid.ok_or_else(|| {
                    anyhow::anyhow!(
                        "segment '{}' ended parked on the device (routing bug)",
                        seg.buffer
                    )
                })?;
                env.put(&seg.buffer, grid);
            }

            // -- virtual time: the shared DES over the same segments ------
            vtime = self.model_segments(&mut servers, &segs, vtime);
            total_passes +=
                segs.iter().map(|s| s.assignment.npasses()).sum::<usize>();
            h2d_elided += segs.iter().filter(|s| s.entry_resident).count();
            d2h_deferred += segs.iter().filter(|s| s.exit_deferred).count();
            roundtrips_elided += batch_plan
                .moves
                .iter()
                .map(|p| p.saved_roundtrips)
                .sum::<usize>();
            if let Some(a) =
                segs.into_iter().last().map(|s| s.assignment)
            {
                self.last_assignment = Some(a);
            }
        }

        let duration_s = vtime - release_s;
        let mut report = DeviceReport {
            tasks_run: tasks.len(),
            virtual_time_s: duration_s,
            release_s,
            finish_s: vtime,
            wall_s: t0.elapsed().as_secs_f64(),
            ..DeviceReport::default()
        };
        servers.absorb_into(&mut report.stats);
        report.stats.virtual_time_s = duration_s;
        report.stats.passes = total_passes;
        report.stats.h2d_elided = h2d_elided;
        report.stats.d2h_deferred = d2h_deferred;
        report.stats.roundtrips_elided = roundtrips_elided;
        if ran_halos {
            // functional wire bytes the exchanges actually framed; the
            // property net checks this equals the DES halo-net accounting
            report.stats.record("halo-wire", halo_wire, 0.0);
        }
        Ok(report)
    }

    /// Communication-aware placement model for `device(any)`: the exact
    /// DES this cluster would time the batch with — same mapper (so the
    /// kernel↔IP skip logic decides compatibility), same per-segment
    /// pass hop sequences across the ring, same byte counts the
    /// functional model moves, same residency elisions — evaluated
    /// against fresh servers starting at 0.  A run whose inputs this
    /// cluster already holds prices without their H2D, which is what
    /// steers `device(any)` placement toward the data (affinity).
    /// `None` when any task resolves to software on this arch (no
    /// `declare variant` for vc709), when no IP in this cluster
    /// implements a required kernel, or when the batch shape is one the
    /// executor would reject: such runs fall back to other devices or
    /// the host.
    fn estimate_batch_s(
        &self,
        graph: &TaskGraph,
        tasks: &[TaskId],
        fn_names: &[String],
        fns: &FnRegistry,
        env: &DataEnv,
        residency: &Residency,
    ) -> Option<f64> {
        if tasks.is_empty() {
            return Some(0.0);
        }
        // Sectioning mirrors run_batch: maximal kernel stretches price
        // through the segment planner, halo stretches through the fabric
        // model, all against one fresh server set and one time cursor.
        // fn_names (the caller's per-arch variant resolutions) decide the
        // flavor, not the graph's stored base names.
        enum Est {
            Kernels(Vec<TaskId>, Vec<Kernel>),
            Halo(HaloOp),
            Band(BandSweep),
        }
        let mut sections: Vec<Est> = Vec::new();
        for (i, name) in fn_names.iter().enumerate() {
            if let Some(op) = fns.halo_of(name) {
                sections.push(Est::Halo(op.clone()));
                continue;
            }
            if let Some(band) = fns.band_of(name) {
                sections.push(Est::Band(band.clone()));
                continue;
            }
            // admission mirrors run_batch exactly: a batch the segment
            // planner rejects (multi-map task, unmappable kernel,
            // dimension mismatch) must make this plugin abstain rather
            // than win placement and fail at execution
            let k = fns.kernel_of(name).ok()?;
            match sections.last_mut() {
                Some(Est::Kernels(ids, ks)) => {
                    ids.push(tasks[i]);
                    ks.push(k);
                }
                _ => sections.push(Est::Kernels(vec![tasks[i]], vec![k])),
            }
        }
        let mut servers = self.build_servers();
        let mut vtime = self.timing.offload_startup_s;
        for section in &sections {
            match section {
                Est::Kernels(ids, kernels) => {
                    // Buffer sizes come from the `env` the caller prices
                    // with: the compiled pipeline (omp::program) passes a
                    // shape-only phantom built from the capture-time
                    // slots — same shapes and byte counts run_batch will
                    // stream, zero values, and a buffer first created by
                    // a mid-region task absent (priced as empty; see the
                    // program module's documented corollary).
                    let segs = self
                        .plan_segments(graph, ids, kernels, env, residency)
                        .ok()?;
                    vtime = self.model_segments(&mut servers, &segs, vtime);
                }
                Est::Halo(op) => {
                    // halo pricing needs only the op's geometry and the
                    // fabric slots baked into it — no buffers consulted,
                    // so the phantom env prices identically to execution
                    vtime = self.model_halo(&mut servers, op, vtime);
                }
                Est::Band(band) => {
                    // band pricing needs only the geometry baked into
                    // the band plus the same residency facts run_batch
                    // reads; a kernel no IP here implements makes the
                    // plugin abstain, mirroring execution's error
                    let entry_resident =
                        residency.device_valid.contains(&band.src);
                    let exit_deferred =
                        residency.resident.contains(&band.dst);
                    vtime = self
                        .model_band(
                            &mut servers,
                            band,
                            entry_resident,
                            exit_deferred,
                            vtime,
                        )
                        .ok()?;
                }
            }
        }
        Some(vtime)
    }

    /// Deferred D2H: one bulk DMA of the resident buffer back over PCIe,
    /// charged when a host flow dependence or an exit-data `from` forces
    /// the writeback.  Bulk beats the chunked in-batch transfer it
    /// replaced (one descriptor setup instead of one per chunk), so
    /// deferring is never modelled slower than streaming eagerly.
    fn writeback_s(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        self.timing.dma_setup_s + bytes * 8.0 / self.timing.pcie_bps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omp::task::{DepVar, Task};

    #[test]
    fn frame_overhead_is_small() {
        assert!(FRAME_OVERHEAD > 1.0 && FRAME_OVERHEAD < 1.01);
    }

    #[test]
    fn group_slots_splits_at_board_crossings() {
        let slots = [
            IpSlot { board: 0, ip: 0 },
            IpSlot { board: 0, ip: 1 },
            IpSlot { board: 2, ip: 0 },
            IpSlot { board: 0, ip: 3 },
        ];
        let g = group_slots(&slots);
        assert_eq!(
            g,
            vec![(0, vec![0, 1]), (2, vec![0]), (0, vec![3])]
        );
        assert!(group_slots(&[]).is_empty());
    }

    #[test]
    fn placement_estimate_matches_run_batch_duration() {
        // the cost model and the executed batch share one DES: the
        // estimate must equal the reported duration exactly, regardless
        // of the batch's release time
        let cfg = ClusterConfig::homogeneous(2, 1, Kernel::Laplace2d);
        let mut plugin = Vc709Plugin::new(&cfg, ExecBackend::Golden).unwrap();
        let mut graph = TaskGraph::new();
        let mut fns = FnRegistry::default();
        fns.register("hw_f", crate::omp::TaskFn::HwKernel(Kernel::Laplace2d));
        let mut ids = Vec::new();
        for i in 0..4 {
            ids.push(graph.add(Task {
                id: TaskId(0),
                base_name: "f".into(),
                fn_name: "hw_f".into(),
                device: crate::omp::DeviceId(1).into(),
                maps: vec![(crate::omp::MapDir::ToFrom, "V".into())],
                deps_in: vec![DepVar(i)],
                deps_out: vec![DepVar(i + 1)],
                nowait: true,
            }));
        }
        let mut env = DataEnv::new();
        env.insert("V", Grid::random(&[16, 12], 2).unwrap());
        let names: Vec<String> = vec!["hw_f".into(); 4];
        let none = Residency::default();
        let est = plugin
            .estimate_batch_s(&graph, &ids, &names, &fns, &env, &none)
            .expect("compatible batch must be priced");
        let rep = plugin
            .run_batch(&graph, &ids, &mut env, &fns, &BatchCtx::at(0.5))
            .unwrap();
        assert!(
            (est - rep.virtual_time_s).abs() < 1e-12,
            "estimate {est} != executed duration {}",
            rep.virtual_time_s
        );
        assert_eq!(rep.stats.roundtrips_elided, 3, "4-task tofrom chain");
        // a kernel the cluster does not implement makes the plugin
        // abstain (mapper skip logic), as does a software resolution
        fns.register("hw_j", crate::omp::TaskFn::HwKernel(Kernel::Jacobi9pt));
        let bad: Vec<String> = vec!["hw_j".into(); 4];
        assert!(plugin
            .estimate_batch_s(&graph, &ids, &bad, &fns, &env, &none)
            .is_none());
        let soft: Vec<String> = vec!["f".into(); 4];
        assert!(plugin
            .estimate_batch_s(&graph, &ids, &soft, &fns, &env, &none)
            .is_none());
    }

    #[test]
    fn band_run_matches_host_band_and_estimate_matches_duration() {
        // a band-restricted sweep streamed through the fabric must be
        // bit-identical to the host row-band path, and its placement
        // estimate must equal the executed duration (same DES)
        let cfg = ClusterConfig::homogeneous(2, 1, Kernel::Laplace2d);
        let mut plugin = Vc709Plugin::new(&cfg, ExecBackend::Golden).unwrap();
        let shape = vec![32, 12];
        let band = BandSweep {
            src: "T".into(),
            dst: "T.pong".into(),
            kernel: Kernel::Laplace2d,
            tile_shape: shape.clone(),
            rows: (3, 20),
        };
        let mut fns = FnRegistry::default();
        fns.register("band", crate::omp::TaskFn::Band(band.clone()));
        let mut graph = TaskGraph::new();
        let id = graph.add(Task {
            id: TaskId(0),
            base_name: "band".into(),
            fn_name: "band".into(),
            device: crate::omp::DeviceId(1).into(),
            maps: vec![(crate::omp::MapDir::ToFrom, "T.pong".into())],
            deps_in: vec![],
            deps_out: vec![DepVar(0)],
            nowait: true,
        });
        let mut env = DataEnv::new();
        let src = Grid::random(&shape, 7).unwrap();
        env.insert("T", src.clone());
        env.insert("T.pong", src.clone());
        let names: Vec<String> = vec!["band".into()];
        let none = Residency::default();
        let est = plugin
            .estimate_batch_s(&graph, &[id], &names, &fns, &env, &none)
            .expect("band batch must be priced");
        let rep = plugin
            .run_batch(&graph, &[id], &mut env, &fns, &BatchCtx::at(0.25))
            .unwrap();
        assert!(
            (est - rep.virtual_time_s).abs() < 1e-12,
            "band estimate {est} != executed duration {}",
            rep.virtual_time_s
        );
        let mut want = src.clone();
        band.sweep_into(&src, &mut want).unwrap();
        assert_eq!(env.get("T.pong").unwrap().data(), want.data());
        assert_eq!(env.get("T").unwrap().data(), src.data());
        // residency facts move the price: a current source elides the
        // H2D and a resident destination defers the D2H
        let mut resident = Residency::default();
        resident.device_valid.insert("T".into());
        resident.resident.insert("T".into());
        resident.resident.insert("T.pong".into());
        let est_res = plugin
            .estimate_batch_s(&graph, &[id], &names, &fns, &env, &resident)
            .unwrap();
        assert!(
            est_res < est,
            "resident band {est_res} should price below streamed {est}"
        );
        // a kernel no IP here implements makes the plugin abstain
        let foreign = BandSweep { kernel: Kernel::Jacobi9pt, ..band.clone() };
        fns.register("band9", crate::omp::TaskFn::Band(foreign));
        let bad: Vec<String> = vec!["band9".into()];
        assert!(plugin
            .estimate_batch_s(&graph, &[id], &bad, &fns, &env, &none)
            .is_none());
    }

    #[test]
    fn injected_failure_is_typed_and_atomic() {
        // the fail knob must (a) surface as a downcastable DeviceFailed
        // stamped at the batch's release instant, (b) leave the data
        // environment bit-identical (nothing streamed), and (c) be
        // consumed — the very next dispatch succeeds
        let cfg = ClusterConfig::homogeneous(1, 1, Kernel::Laplace2d);
        let mut plugin = Vc709Plugin::new(&cfg, ExecBackend::Golden).unwrap();
        let mut graph = TaskGraph::new();
        let mut fns = FnRegistry::default();
        fns.register("hw_f", crate::omp::TaskFn::HwKernel(Kernel::Laplace2d));
        let id = graph.add(Task {
            id: TaskId(0),
            base_name: "f".into(),
            fn_name: "hw_f".into(),
            device: crate::omp::DeviceId(1).into(),
            maps: vec![(crate::omp::MapDir::ToFrom, "V".into())],
            deps_in: vec![],
            deps_out: vec![DepVar(0)],
            nowait: true,
        });
        let mut env = DataEnv::new();
        env.insert("V", Grid::random(&[16, 12], 5).unwrap());
        let before = env.get("V").unwrap().clone();
        plugin.fail_next_batch = Some("link drop (injected)".into());
        let err = plugin
            .run_batch(&graph, &[id], &mut env, &fns, &BatchCtx::at(1.25))
            .expect_err("armed plugin must fail");
        let df = err
            .downcast_ref::<crate::omp::DeviceFailed>()
            .expect("typed DeviceFailed, not a stringly error");
        assert_eq!(df.at_s, 1.25);
        assert!(df.cause.contains("link drop"));
        assert_eq!(
            env.get("V").unwrap().data(),
            before.data(),
            "failed dispatch must not touch the data environment"
        );
        // consumed: the retry dispatch runs clean
        plugin
            .run_batch(&graph, &[id], &mut env, &fns, &BatchCtx::at(1.25))
            .expect("knob is one-shot");
    }

    #[test]
    fn zero_copy_stream_matches_naive_bit_exactly() {
        // single-board VFIFO loop-backs, fused same-kernel chains, ring
        // crossings with wrap, and multi-pass ring shapes: the zero-copy
        // engine and the retained pre-PR clone-per-step path must agree
        // bit-for-bit on grids, timing and IP accounting
        let kernel = Kernel::Diffusion2d;
        let input = Grid::random(&[12, 10], 7).unwrap();
        for (boards, ips, tasks) in
            [(1usize, 1usize, 4usize), (1, 2, 4), (3, 1, 5), (2, 2, 3)]
        {
            let cfg = ClusterConfig::homogeneous(boards, ips, kernel);
            let mut graph = TaskGraph::new();
            let mut fns = FnRegistry::default();
            fns.register("hw_f", crate::omp::TaskFn::HwKernel(kernel));
            let mut ids = Vec::new();
            for i in 0..tasks {
                ids.push(graph.add(Task {
                    id: TaskId(0),
                    base_name: "f".into(),
                    fn_name: "hw_f".into(),
                    device: crate::omp::DeviceId(1).into(),
                    maps: vec![(crate::omp::MapDir::ToFrom, "V".into())],
                    deps_in: vec![DepVar(i)],
                    deps_out: vec![DepVar(i + 1)],
                    nowait: true,
                }));
            }
            let run = |naive: bool| {
                let mut plugin =
                    Vc709Plugin::new(&cfg, ExecBackend::Golden).unwrap();
                plugin.naive_stream = naive;
                let mut env = DataEnv::new();
                env.insert("V", input.clone());
                let rep = plugin
                    .run_batch(&graph, &ids, &mut env, &fns, &BatchCtx::at(0.25))
                    .unwrap();
                let invocations: Vec<u64> = plugin
                    .cluster
                    .boards
                    .iter()
                    .flat_map(|b| b.ips.iter().map(|ip| ip.invocations))
                    .collect();
                (
                    env.take("V").unwrap(),
                    rep.release_s,
                    rep.finish_s,
                    rep.stats.passes,
                    invocations,
                )
            };
            let zero = run(false);
            let naive = run(true);
            assert_eq!(zero, naive, "{boards} boards x {ips} IPs, {tasks} tasks");
            // and both equal the retained host reference
            let want = kernel.iterate(&input, tasks).unwrap();
            assert_eq!(zero.0, want, "{boards}x{ips}: grid diverged");
        }
    }

    fn two_buffer_chain() -> (TaskGraph, Vec<TaskId>) {
        let mut graph = TaskGraph::new();
        let mut ids = Vec::new();
        for (i, buf) in ["A", "B"].iter().enumerate() {
            ids.push(graph.add(Task {
                id: TaskId(0),
                base_name: "f".into(),
                fn_name: "hw_f".into(),
                device: crate::omp::DeviceSel::Any,
                maps: vec![(crate::omp::MapDir::ToFrom, (*buf).into())],
                deps_in: vec![DepVar(i)],
                deps_out: vec![DepVar(i + 1)],
                nowait: true,
            }));
        }
        (graph, ids)
    }

    #[test]
    fn mixed_buffer_chain_prices_and_executes() {
        // a chain whose tasks map different buffers — the Jacobi-style
        // ping-pong shape the old coalescer rejected — now plans as two
        // segments; the estimate still equals the executed duration
        let cfg = ClusterConfig::homogeneous(1, 2, Kernel::Laplace2d);
        let mut plugin = Vc709Plugin::new(&cfg, ExecBackend::Golden).unwrap();
        let mut fns = FnRegistry::default();
        fns.register("hw_f", crate::omp::TaskFn::HwKernel(Kernel::Laplace2d));
        let (graph, ids) = two_buffer_chain();
        let ga = Grid::random(&[8, 8], 1).unwrap();
        let gb = Grid::random(&[8, 8], 2).unwrap();
        let mut env = DataEnv::new();
        env.insert("A", ga.clone());
        env.insert("B", gb.clone());
        let names: Vec<String> = vec!["hw_f".into(); 2];
        let none = Residency::default();
        let est = plugin
            .estimate_batch_s(&graph, &ids, &names, &fns, &env, &none)
            .expect("two-buffer chains are schedulable now");
        let rep = plugin
            .run_batch(&graph, &ids, &mut env, &fns, &BatchCtx::at(0.0))
            .unwrap();
        assert!((est - rep.virtual_time_s).abs() < 1e-12);
        // each buffer advanced by exactly its own task
        assert_eq!(env.take("A").unwrap(), Kernel::Laplace2d.apply(&ga).unwrap());
        assert_eq!(env.take("B").unwrap(), Kernel::Laplace2d.apply(&gb).unwrap());
        // no residency, no same-buffer reuse: nothing elided or deferred
        assert_eq!(rep.stats.h2d_elided, 0);
        assert_eq!(rep.stats.d2h_deferred, 0);
    }

    #[test]
    fn resident_buffer_elides_h2d_and_defers_d2h() {
        let cfg = ClusterConfig::homogeneous(1, 2, Kernel::Laplace2d);
        let mut plugin = Vc709Plugin::new(&cfg, ExecBackend::Golden).unwrap();
        let mut fns = FnRegistry::default();
        fns.register("hw_f", crate::omp::TaskFn::HwKernel(Kernel::Laplace2d));
        let mut graph = TaskGraph::new();
        let mut ids = Vec::new();
        for i in 0..2 {
            ids.push(graph.add(Task {
                id: TaskId(0),
                base_name: "f".into(),
                fn_name: "hw_f".into(),
                device: crate::omp::DeviceId(1).into(),
                maps: vec![(crate::omp::MapDir::ToFrom, "V".into())],
                deps_in: vec![DepVar(i)],
                deps_out: vec![DepVar(i + 1)],
                nowait: true,
            }));
        }
        let input = Grid::random(&[16, 12], 4).unwrap();
        let run = |plugin: &mut Vc709Plugin, ctx: &BatchCtx| {
            let mut env = DataEnv::new();
            env.insert("V", input.clone());
            let rep = plugin.run_batch(&graph, &ids, &mut env, &fns, ctx).unwrap();
            (rep, env.take("V").unwrap())
        };
        let (stream, g_stream) = run(&mut plugin, &BatchCtx::at(0.0));
        let mut resident = BatchCtx::at(0.0);
        resident.residency.resident.insert("V".into());
        resident.residency.device_valid.insert("V".into());
        let (res, g_res) = run(&mut plugin, &resident);
        assert_eq!(res.stats.h2d_elided, 1);
        assert_eq!(res.stats.d2h_deferred, 1);
        assert!(
            res.virtual_time_s < stream.virtual_time_s,
            "residency must be cheaper: {} vs {}",
            res.virtual_time_s,
            stream.virtual_time_s
        );
        // residency is a timing-plane concept: numerics are identical
        assert_eq!(g_res, g_stream);
        // and the estimate tracks the residency-adjusted duration exactly
        let names: Vec<String> = vec!["hw_f".into(); 2];
        let mut env = DataEnv::new();
        env.insert("V", input.clone());
        let est = plugin
            .estimate_batch_s(&graph, &ids, &names, &fns, &env, &resident.residency)
            .unwrap();
        assert!((est - res.virtual_time_s).abs() < 1e-12);
        // a resident buffer never written back for free
        assert!(plugin.writeback_s(input.bytes() as f64) > 0.0);
        assert_eq!(plugin.writeback_s(0.0), 0.0);
    }

    /// One halo task: copy 2 rows (rows 6..8 of `T0`) into rows 0..2 of
    /// `T1`, between the given fabric slots.
    fn halo_fixture(
        src_slot: usize,
        dst_slot: usize,
    ) -> (TaskGraph, Vec<TaskId>, FnRegistry, DataEnv) {
        let op = HaloOp {
            src: "T0".into(),
            dst: "T1".into(),
            src_row0: 6,
            dst_row0: 0,
            nrows: 2,
            row_cells: 12,
            src_slot,
            dst_slot,
        };
        let mut fns = FnRegistry::default();
        fns.register("halo_x", crate::omp::TaskFn::Halo(op));
        let mut graph = TaskGraph::new();
        let id = graph.add(Task {
            id: TaskId(0),
            base_name: "halo_x".into(),
            fn_name: "halo_x".into(),
            device: crate::omp::DeviceId(1).into(),
            maps: vec![(crate::omp::MapDir::ToFrom, "T1".into())],
            deps_in: vec![],
            deps_out: vec![DepVar(0)],
            nowait: true,
        });
        let mut env = DataEnv::new();
        env.insert("T0", Grid::random(&[8, 12], 11).unwrap());
        env.insert("T1", Grid::random(&[8, 12], 12).unwrap());
        (graph, vec![id], fns, env)
    }

    #[test]
    fn halo_task_moves_rows_and_estimate_matches_duration() {
        let cfg = ClusterConfig::homogeneous(1, 1, Kernel::Laplace2d);
        let mut plugin = Vc709Plugin::new(&cfg, ExecBackend::Golden).unwrap();
        plugin.fabric =
            crate::hw::FabricSlot::new(Topology::Ring, 4, 1).unwrap();
        let (graph, ids, fns, mut env) = halo_fixture(0, 1);
        let src_before = env.get("T0").unwrap().clone();
        let dst_before = env.get("T1").unwrap().clone();
        let names: Vec<String> = vec!["halo_x".into()];
        let none = Residency::default();
        let est = plugin
            .estimate_batch_s(&graph, &ids, &names, &fns, &env, &none)
            .expect("halo batches must be priced, not abstained");
        let rep = plugin
            .run_batch(&graph, &ids, &mut env, &fns, &BatchCtx::at(0.75))
            .unwrap();
        assert!(
            (est - rep.virtual_time_s).abs() < 1e-12,
            "halo estimate {est} != executed duration {}",
            rep.virtual_time_s
        );
        assert!(rep.virtual_time_s > 0.0);
        // rows 6..8 of the source landed in rows 0..2 of the destination,
        // bit-identically; everything else untouched
        let src = env.get("T0").unwrap();
        let dst = env.get("T1").unwrap();
        assert_eq!(src.data(), src_before.data(), "halo must not write src");
        assert_eq!(&dst.data()[..24], &src_before.data()[72..96]);
        assert_eq!(&dst.data()[24..], &dst_before.data()[24..]);
        // functional wire bytes == DES halo-net accounting, exactly:
        // same frame segmentation, same per-link replication
        let wire = rep.stats.modules["halo-wire"].bytes;
        let priced = rep.stats.modules["halo-net"].bytes;
        assert!(wire > 0.0, "a 1-hop exchange puts bytes on the wire");
        assert_eq!(wire, priced, "halo bytes must equal priced bytes");
    }

    #[test]
    fn halo_pricing_follows_topology_hops() {
        // slot 1 -> slot 0 is the expensive direction on a directed
        // 4-ring (3 store-and-forward hops) but a single hop on the
        // crossbar; both must execute bit-identically, price
        // estimate == duration, and the ring must be strictly slower
        let cfg = ClusterConfig::homogeneous(1, 1, Kernel::Laplace2d);
        let mut durations = Vec::new();
        for topology in [Topology::Ring, Topology::Crossbar] {
            let mut plugin =
                Vc709Plugin::new(&cfg, ExecBackend::Golden).unwrap();
            plugin.fabric =
                crate::hw::FabricSlot::new(topology, 4, 0).unwrap();
            let (graph, ids, fns, mut env) = halo_fixture(1, 0);
            let names: Vec<String> = vec!["halo_x".into()];
            let est = plugin
                .estimate_batch_s(
                    &graph,
                    &ids,
                    &names,
                    &fns,
                    &env,
                    &Residency::default(),
                )
                .unwrap();
            let rep = plugin
                .run_batch(&graph, &ids, &mut env, &fns, &BatchCtx::at(0.0))
                .unwrap();
            assert!((est - rep.virtual_time_s).abs() < 1e-12, "{topology:?}");
            let wire = rep.stats.modules["halo-wire"].bytes;
            let priced = rep.stats.modules["halo-net"].bytes;
            assert_eq!(wire, priced, "{topology:?}");
            durations.push((rep.virtual_time_s, wire, env.take("T1").unwrap()));
        }
        let (ring, crossbar) = (&durations[0], &durations[1]);
        assert!(
            ring.0 > crossbar.0,
            "3-hop ring path must outprice the 1-hop crossbar: {} vs {}",
            ring.0,
            crossbar.0
        );
        assert_eq!(ring.1, crossbar.1 * 3.0, "bytes scale with hop count");
        assert_eq!(ring.2, crossbar.2, "topology is timing-plane only");
    }

    #[test]
    fn same_slot_halo_stays_on_chip() {
        let cfg = ClusterConfig::homogeneous(1, 1, Kernel::Laplace2d);
        let mut plugin = Vc709Plugin::new(&cfg, ExecBackend::Golden).unwrap();
        let (graph, ids, fns, mut env) = halo_fixture(0, 0);
        let rep = plugin
            .run_batch(&graph, &ids, &mut env, &fns, &BatchCtx::at(0.0))
            .unwrap();
        assert_eq!(
            rep.stats.modules["halo-wire"].bytes, 0.0,
            "same-slot exchange must not touch the fabric"
        );
        assert!(!rep.stats.modules.contains_key("halo-net") || {
            rep.stats.modules["halo-net"].bytes == 0.0
        });
    }
}
