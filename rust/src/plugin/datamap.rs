//! `map`-clause coalescing.
//!
//! Listing 3 maps `V` `tofrom` on *every* task, which naively means a
//! host round-trip per iteration.  "The implemented mapping algorithm
//! concludes that vector V is sent to the IP from the host memory and its
//! output forwarded to the next IP in the following iteration" (§III-A):
//! with the whole graph visible at the sync point, interior transfers
//! collapse into IP->IP streams.

use anyhow::{bail, Result};

use crate::omp::graph::TaskGraph;
use crate::omp::task::TaskId;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MovePlan {
    /// the pipelined buffer
    pub buffer: String,
    /// host -> device before the first task (it maps `to`/`tofrom`)
    pub h2d: bool,
    /// device -> host after the last task (it maps `from`/`tofrom`)
    pub d2h: bool,
    /// host round-trips eliminated by coalescing
    pub saved_roundtrips: usize,
}

/// Plan data movement for a chain batch.  Every task must map exactly one
/// buffer and it must be the same buffer (the paper's pipelines; richer
/// layouts would extend this analysis, not the mechanism).
pub fn coalesce(graph: &TaskGraph, tasks: &[TaskId]) -> Result<MovePlan> {
    if tasks.is_empty() {
        bail!("empty device batch");
    }
    let first = graph.task(tasks[0]);
    if first.maps.len() != 1 {
        bail!(
            "task {} maps {} buffers; the VC709 plugin streams exactly one \
             grid per pipeline",
            first.id.0,
            first.maps.len()
        );
    }
    let buffer = first.maps[0].1.clone();
    for id in tasks {
        let t = graph.task(*id);
        if t.maps.len() != 1 || t.maps[0].1 != buffer {
            bail!(
                "task {} maps '{}' but the pipeline streams '{}' — \
                 mixed-buffer pipelines are not supported",
                id.0,
                t.maps.first().map(|(_, n)| n.as_str()).unwrap_or("<none>"),
                buffer
            );
        }
    }
    let h2d = graph.task(tasks[0]).maps[0].0.to_device();
    let d2h = graph.task(*tasks.last().unwrap()).maps[0].0.from_device();
    // every interior tofrom would have been a d2h+h2d round-trip
    let saved = tasks.len().saturating_sub(1);
    Ok(MovePlan { buffer, h2d, d2h, saved_roundtrips: saved })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omp::device::DeviceId;
    use crate::omp::task::{DepVar, MapDir, Task};

    fn chain(n: usize, dir: MapDir, buf: &str) -> (TaskGraph, Vec<TaskId>) {
        let mut g = TaskGraph::new();
        let mut ids = Vec::new();
        for i in 0..n {
            ids.push(g.add(Task {
                id: TaskId(0),
                base_name: "f".into(),
                fn_name: "hw_f".into(),
                device: DeviceId(1).into(),
                maps: vec![(dir, buf.into())],
                deps_in: vec![DepVar(i)],
                deps_out: vec![DepVar(i + 1)],
                nowait: true,
            }));
        }
        (g, ids)
    }

    #[test]
    fn listing3_tofrom_chain() {
        let (g, ids) = chain(240, MapDir::ToFrom, "V");
        let plan = coalesce(&g, &ids).unwrap();
        assert_eq!(plan.buffer, "V");
        assert!(plan.h2d && plan.d2h);
        assert_eq!(plan.saved_roundtrips, 239);
    }

    #[test]
    fn directions_respected() {
        let (g, ids) = chain(4, MapDir::To, "V");
        let plan = coalesce(&g, &ids).unwrap();
        assert!(plan.h2d && !plan.d2h);
        let (g, ids) = chain(4, MapDir::From, "V");
        let plan = coalesce(&g, &ids).unwrap();
        assert!(!plan.h2d && plan.d2h);
    }

    #[test]
    fn mixed_buffers_rejected() {
        let (mut g, mut ids) = chain(2, MapDir::ToFrom, "V");
        ids.push(g.add(Task {
            id: TaskId(0),
            base_name: "f".into(),
            fn_name: "hw_f".into(),
            device: DeviceId(1).into(),
            maps: vec![(MapDir::ToFrom, "W".into())],
            deps_in: vec![DepVar(2)],
            deps_out: vec![DepVar(3)],
            nowait: true,
        }));
        assert!(coalesce(&g, &ids).is_err());
        assert!(coalesce(&g, &[]).is_err());
    }
}
