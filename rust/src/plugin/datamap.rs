//! `map`-clause coalescing.
//!
//! Listing 3 maps `V` `tofrom` on *every* task, which naively means a
//! host round-trip per iteration.  "The implemented mapping algorithm
//! concludes that vector V is sent to the IP from the host memory and its
//! output forwarded to the next IP in the following iteration" (§III-A):
//! with the whole graph visible at the sync point, interior transfers
//! collapse into IP->IP streams.
//!
//! A pipeline may touch **several** buffers (a Jacobi-style ping-pong
//! alternates `A`/`Anew`; a wave kernel rotates `prev`/`cur`/`next`):
//! [`coalesce`] returns one [`MovePlan`] per distinct buffer, in
//! first-use order, and [`segments`] splits the chain into maximal
//! same-buffer sub-chains — the unit the VC709 plugin streams through an
//! IP pipeline.  Between two segments of the *same* buffer the grid
//! parks on the device, so the interior transfers those map clauses
//! imply are elided exactly like Listing 3's.

use anyhow::{bail, Result};

use crate::omp::graph::TaskGraph;
use crate::omp::task::TaskId;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MovePlan {
    /// the pipelined buffer
    pub buffer: String,
    /// host -> device before the buffer's first task (it maps `to`/`tofrom`)
    pub h2d: bool,
    /// device -> host after the buffer's last task (it maps `from`/`tofrom`)
    pub d2h: bool,
    /// interior host round-trips eliminated by coalescing: a round-trip
    /// exists between consecutive uses only when the earlier use maps
    /// `from`/`tofrom` (a d2h would have happened) **and** the later use
    /// maps `to`/`tofrom` (an h2d would have followed) — a `to`-only or
    /// `from`-only chain has no interior round-trips at all
    pub saved_roundtrips: usize,
}

/// One maximal same-buffer sub-chain of a batch — the unit the VC709
/// plugin maps onto an IP pipeline and streams in passes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    pub buffer: String,
    /// tasks of the segment, in chain order
    pub tasks: Vec<TaskId>,
}

/// The single buffer a pipeline task maps, validated: the VC709 plugin
/// streams exactly one grid per task (multi-map tasks would need a
/// gather/scatter datapath the substrate does not model).
fn sole_buffer<'g>(graph: &'g TaskGraph, id: TaskId) -> Result<&'g str> {
    let t = graph.task(id);
    if t.maps.len() != 1 {
        bail!(
            "task {} maps {} buffers; the VC709 plugin streams exactly one \
             grid per task",
            t.id.0,
            t.maps.len()
        );
    }
    Ok(t.maps[0].1.as_str())
}

/// The full data-movement analysis of one chain batch — the plan-reuse
/// entry point: both views ([`MovePlan`]s and [`Segment`]s) computed in
/// a single walk and reusable for as long as the batch's task list is
/// unchanged, which is how the VC709 plugin avoids re-walking the chain
/// per view and how compiled programs (`omp::program`) keep replays
/// free of re-analysis.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPlan {
    /// one [`MovePlan`] per distinct buffer, in first-use order
    pub moves: Vec<MovePlan>,
    /// maximal same-buffer sub-chains, in chain order
    pub segments: Vec<Segment>,
}

/// Analyze a chain batch in one walk: per-buffer [`MovePlan`]s *and*
/// the same-buffer [`Segment`] split.  Every task must map exactly one
/// buffer; tasks touching different buffers may interleave freely.
pub fn plan(graph: &TaskGraph, tasks: &[TaskId]) -> Result<BatchPlan> {
    if tasks.is_empty() {
        bail!("empty device batch");
    }
    // buffer -> map directions of its uses, in chain order
    let mut order: Vec<String> = Vec::new();
    let mut uses: Vec<Vec<crate::omp::task::MapDir>> = Vec::new();
    let mut segs: Vec<Segment> = Vec::new();
    for id in tasks {
        let buf = sole_buffer(graph, *id)?;
        let dir = graph.task(*id).maps[0].0;
        match order.iter().position(|b| b == buf) {
            Some(i) => uses[i].push(dir),
            None => {
                order.push(buf.to_string());
                uses.push(vec![dir]);
            }
        }
        match segs.last_mut() {
            Some(s) if s.buffer == buf => s.tasks.push(*id),
            _ => segs.push(Segment { buffer: buf.to_string(), tasks: vec![*id] }),
        }
    }
    let moves = order
        .into_iter()
        .zip(uses)
        .map(|(buffer, dirs)| {
            let saved = dirs
                .windows(2)
                .filter(|w| w[0].from_device() && w[1].to_device())
                .count();
            let (Some(first), Some(last)) = (dirs.first(), dirs.last())
            else {
                bail!(
                    "buffer '{buffer}' recorded no uses in the batch \
                     walk — data-movement planner bug"
                );
            };
            Ok(MovePlan {
                buffer,
                h2d: first.to_device(),
                d2h: last.from_device(),
                saved_roundtrips: saved,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(BatchPlan { moves, segments: segs })
}

/// Plan data movement for a chain batch: one [`MovePlan`] per distinct
/// buffer, in first-use order.  Thin view over [`plan`].
pub fn coalesce(graph: &TaskGraph, tasks: &[TaskId]) -> Result<Vec<MovePlan>> {
    Ok(plan(graph, tasks)?.moves)
}

/// Split a chain batch into maximal same-buffer [`Segment`]s, in chain
/// order.  `[A, A, B, A]` becomes `[A×2], [B], [A]` — the middle `B`
/// segment streams while `A` stays parked on the device.  Thin view
/// over [`plan`].
pub fn segments(graph: &TaskGraph, tasks: &[TaskId]) -> Result<Vec<Segment>> {
    Ok(plan(graph, tasks)?.segments)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omp::device::DeviceId;
    use crate::omp::task::{DepVar, MapDir, Task};

    fn push_task(
        g: &mut TaskGraph,
        i: usize,
        maps: Vec<(MapDir, String)>,
    ) -> TaskId {
        g.add(Task {
            id: TaskId(0),
            base_name: "f".into(),
            fn_name: "hw_f".into(),
            device: DeviceId(1).into(),
            maps,
            deps_in: vec![DepVar(i)],
            deps_out: vec![DepVar(i + 1)],
            nowait: true,
        })
    }

    fn chain(n: usize, dir: MapDir, buf: &str) -> (TaskGraph, Vec<TaskId>) {
        let mut g = TaskGraph::new();
        let mut ids = Vec::new();
        for i in 0..n {
            ids.push(push_task(&mut g, i, vec![(dir, buf.into())]));
        }
        (g, ids)
    }

    #[test]
    fn listing3_tofrom_chain() {
        let (g, ids) = chain(240, MapDir::ToFrom, "V");
        let plans = coalesce(&g, &ids).unwrap();
        assert_eq!(plans.len(), 1);
        let plan = &plans[0];
        assert_eq!(plan.buffer, "V");
        assert!(plan.h2d && plan.d2h);
        assert_eq!(plan.saved_roundtrips, 239);
        let segs = segments(&g, &ids).unwrap();
        assert_eq!(segs.len(), 1);
        assert_eq!(segs[0].tasks.len(), 240);
    }

    #[test]
    fn directions_respected() {
        // a `to`-only chain never sends data back, so there are no
        // interior *round-trips* to save — and symmetrically for `from`
        let (g, ids) = chain(4, MapDir::To, "V");
        let plan = &coalesce(&g, &ids).unwrap()[0];
        assert!(plan.h2d && !plan.d2h);
        assert_eq!(plan.saved_roundtrips, 0, "to-only chain has no round-trips");
        let (g, ids) = chain(4, MapDir::From, "V");
        let plan = &coalesce(&g, &ids).unwrap()[0];
        assert!(!plan.h2d && plan.d2h);
        assert_eq!(plan.saved_roundtrips, 0, "from-only chain has no round-trips");
    }

    #[test]
    fn mixed_direction_roundtrips_count_only_real_pairs() {
        // to, tofrom, from: one elided round-trip (tofrom -> from); the
        // to -> tofrom boundary elides the interior h2d only, which is
        // not a round-trip
        let mut g = TaskGraph::new();
        let ids = vec![
            push_task(&mut g, 0, vec![(MapDir::To, "V".into())]),
            push_task(&mut g, 1, vec![(MapDir::ToFrom, "V".into())]),
            push_task(&mut g, 2, vec![(MapDir::From, "V".into())]),
        ];
        let plan = &coalesce(&g, &ids).unwrap()[0];
        assert!(plan.h2d && plan.d2h);
        assert_eq!(plan.saved_roundtrips, 1);
    }

    #[test]
    fn two_buffer_pingpong_plans_per_buffer() {
        // A, B, A, B: the Jacobi ping-pong shape the old coalescer
        // rejected with "mixed-buffer pipelines are not supported"
        let mut g = TaskGraph::new();
        let mut ids = Vec::new();
        for (i, buf) in ["A", "B", "A", "B"].iter().enumerate() {
            ids.push(push_task(
                &mut g,
                i,
                vec![(MapDir::ToFrom, (*buf).to_string())],
            ));
        }
        let plans = coalesce(&g, &ids).unwrap();
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].buffer, "A");
        assert_eq!(plans[1].buffer, "B");
        // each buffer's two uses elide one interior round-trip
        assert_eq!(plans[0].saved_roundtrips, 1);
        assert_eq!(plans[1].saved_roundtrips, 1);
        let segs = segments(&g, &ids).unwrap();
        assert_eq!(segs.len(), 4, "alternating buffers split per task");
        assert!(segs.iter().all(|s| s.tasks.len() == 1));
    }

    #[test]
    fn segments_group_maximal_same_buffer_runs() {
        let mut g = TaskGraph::new();
        let mut ids = Vec::new();
        for (i, buf) in ["A", "A", "B", "A"].iter().enumerate() {
            ids.push(push_task(
                &mut g,
                i,
                vec![(MapDir::ToFrom, (*buf).to_string())],
            ));
        }
        let segs = segments(&g, &ids).unwrap();
        assert_eq!(segs.len(), 3);
        assert_eq!(segs[0].buffer, "A");
        assert_eq!(segs[0].tasks.len(), 2);
        assert_eq!(segs[1].buffer, "B");
        assert_eq!(segs[2].buffer, "A");
    }

    #[test]
    fn plan_computes_both_views_consistently() {
        let mut g = TaskGraph::new();
        let mut ids = Vec::new();
        for (i, buf) in ["A", "A", "B", "A"].iter().enumerate() {
            ids.push(push_task(
                &mut g,
                i,
                vec![(MapDir::ToFrom, (*buf).to_string())],
            ));
        }
        let bp = plan(&g, &ids).unwrap();
        assert_eq!(bp.moves, coalesce(&g, &ids).unwrap());
        assert_eq!(bp.segments, segments(&g, &ids).unwrap());
        assert_eq!(bp.moves.len(), 2);
        assert_eq!(bp.segments.len(), 3);
        assert!(plan(&g, &[]).is_err());
    }

    #[test]
    fn multi_map_task_and_empty_batch_rejected() {
        let mut g = TaskGraph::new();
        let id = push_task(
            &mut g,
            0,
            vec![
                (MapDir::ToFrom, "V".into()),
                (MapDir::ToFrom, "W".into()),
            ],
        );
        let err = coalesce(&g, &[id]).unwrap_err();
        assert!(err.to_string().contains("exactly one grid"), "{err}");
        assert!(segments(&g, &[id]).is_err());
        assert!(coalesce(&g, &[]).is_err());
        assert!(segments(&g, &[]).is_err());
    }
}
