//! The VC709 libomptarget plugin — the paper's §III-B contribution.
//!
//! Receives the deferred task graph from the OpenMP runtime and:
//! 1. maps tasks to the cluster's IPs round-robin over the ring, closest
//!    free IP to the host first ([`mapper`]);
//! 2. coalesces `map` clauses so data moves host->FPGA once, IP->IP in
//!    between, FPGA->host once ([`datamap`]);
//! 3. programs every board's CONF registers (switch routes from the
//!    dependence edges, MFH MAC pairs for board crossings) and executes
//!    the pass schedule, functionally (data really flows through the
//!    switch/MFH/NET models) and in virtual time ([`vc709`]).
//!
//! The numeric step itself is pluggable ([`backend`]): the PJRT executor
//! running the AOT Pallas artifacts (the shipped configuration), the Rust
//! golden model (differential testing), or a timing-only null backend for
//! figure sweeps.

pub mod backend;
pub mod datamap;
pub mod mapper;
pub mod vc709;

pub use backend::{ExecBackend, GoldenExec, PjrtExec, TimingOnlyExec};
pub use mapper::{Assignment, IpSlot};
pub use vc709::Vc709Plugin;
