"""L2 perf tool: static analysis of the lowered HLO artifacts.

Usage:  cd python && python -m compile.inspect_hlo [--dir ../artifacts]

Reports, per artifact: op histogram, fusion count, estimated live-buffer
footprint (the VMEM-budget proxy for the TPU mapping, DESIGN.md §8), and
whether the donated-input alias survived lowering.  Used by the §Perf L2
pass to confirm there is no redundant recompute and fusion happened.
"""

from __future__ import annotations

import argparse
import json
import os
import re
from collections import Counter


# `name = type[shape]{layout} opname(args)` — the op name is the token
# right before the argument list, after the result type.
OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*\(?[a-z0-9]+\[[^=]*?\s([a-z][a-z0-9-]*)\("
)
SHAPE_RE = re.compile(r"\bf32\[([\d,]+)\]")


def analyze_text(text: str) -> dict:
    ops = Counter()
    max_elems = 0
    for line in text.splitlines():
        m = OP_RE.match(line)
        if m:
            ops[m.group(1)] += 1
        for s in SHAPE_RE.findall(line):
            elems = 1
            for d in s.split(","):
                elems *= int(d)
            max_elems = max(max_elems, elems)
    return {
        "ops": dict(ops),
        "total_ops": sum(ops.values()),
        "fusions": ops.get("fusion", 0),
        "max_buffer_mib": max_elems * 4 / (1 << 20),
        "aliased_io": "input_output_alias" in text,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default="../artifacts")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    manifest = json.load(open(os.path.join(args.dir, "manifest.json")))
    rows = []
    for e in manifest["artifacts"]:
        text = open(os.path.join(args.dir, e["file"])).read()
        a = analyze_text(text)
        a["name"] = e["name"]
        rows.append(a)
    if args.json:
        print(json.dumps(rows, indent=1))
        return
    print(f"{'artifact':<42} {'ops':>5} {'fus':>4} {'maxbuf':>9} alias")
    for a in rows:
        print(
            f"{a['name']:<42} {a['total_ops']:>5} {a['fusions']:>4} "
            f"{a['max_buffer_mib']:>7.2f}Mi {a['aliased_io']}"
        )


if __name__ == "__main__":
    main()
