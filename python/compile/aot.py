"""AOT bridge: lower every (kernel x shape x variant) to HLO **text**.

HLO text — not ``lowered.compile()`` or a serialized ``HloModuleProto`` —
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.

Outputs (under --out, default ../artifacts):
  <name>.hlo.txt      one per artifact
  manifest.json       index the Rust runtime::registry parses

Run once via ``make artifacts``; never at request time.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
from typing import Tuple

import jax

from . import model
from .kernels import common


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, shape: Tuple[int, ...]) -> str:
    spec = jax.ShapeDtypeStruct(shape, jax.numpy.float32)
    # donate_argnums: the grid buffer is dead after the step — lets XLA
    # update in place when the backend supports it.
    lowered = jax.jit(fn, donate_argnums=(0,)).lower(spec)
    return to_hlo_text(lowered)


def artifact_list():
    """Every artifact we ship: per-kernel step at paper + small shapes,
    plus fused chains for the multi-IP-per-FPGA kernels."""
    arts = []
    for name in sorted(model.TABLE_II):
        paper_shape, _iters, ips = model.TABLE_II[name]
        small_shape = model.SMALL[name]
        for tag, shape in (("paper", paper_shape), ("small", small_shape)):
            arts.append(
                dict(kind="step", kernel=name, tag=tag, shape=shape, k=1)
            )
        # Fused k-IP chain (single-load fast path) for kernels that place
        # more than one IP per FPGA in Table II; small-shape chain for all
        # kernels so tests can cross-check step-by-step vs fused execution.
        if ips > 1:
            arts.append(
                dict(kind="chain", kernel=name, tag="paper",
                     shape=paper_shape, k=ips)
            )
        arts.append(
            dict(kind="chain", kernel=name, tag="small", shape=small_shape,
                 k=4)
        )
    return arts


def art_name(a) -> str:
    shape = "x".join(str(d) for d in a["shape"])
    if a["kind"] == "step":
        return f"{a['kernel']}_{a['tag']}_{shape}"
    return f"{a['kernel']}_{a['tag']}_{shape}_chain{a['k']}"


def build(out_dir: str, only: str | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for a in artifact_list():
        name = art_name(a)
        if only and only not in name:
            continue
        shape = tuple(a["shape"])
        if a["kind"] == "step":
            fn = model.step_fn(a["kernel"], shape)
        else:
            fn = model.chain_fn(a["kernel"], shape, a["k"])
        text = lower_fn(fn, shape)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        entries.append(
            {
                "name": name,
                "kernel": a["kernel"],
                "kind": a["kind"],
                "tag": a["tag"],
                "shape": list(shape),
                "iters_fused": a["k"],
                "flops_per_cell": common.FLOPS_PER_CELL[a["kernel"]],
                "file": f"{name}.hlo.txt",
                "sha256_16": digest,
                "dtype": "f32",
            }
        )
        print(f"  lowered {name}  ({len(text)} chars)", flush=True)
    manifest = {
        "format": 1,
        "jax_version": jax.__version__,
        "interchange": "hlo-text",
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args()
    build(args.out, args.only)


if __name__ == "__main__":
    main()
