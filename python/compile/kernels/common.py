"""Shared machinery for the five Table-I stencil IP kernels.

Each paper IP is a shift-register + 8-PE datapath streaming a fp32 grid at
8 cells/cycle.  The TPU re-think (DESIGN.md §Hardware-Adaptation): the
temporal shift-register schedule becomes a spatial VMEM row-block schedule —
each Pallas program produces one row-block of the output and reads the
row-block plus a 1-cell halo from the (padded) input.  The 8 PEs become the
VPU lane dimension.

Boundary policy (identical in ref.py, the Rust golden model, and the FLOP
accounting): border cells copy through unchanged, interior cells update.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# ---------------------------------------------------------------------------
# Kernel coefficient sets (the C* constants "passed to the IPs", Table I).
# Fixed at synthesis time in the paper; fixed at AOT-lowering time here.
# ---------------------------------------------------------------------------

#: Diffusion-2D: C1..C5 over (W, N, C, S, E) — diffusion-stable, sums to 1.
DIFFUSION2D_C = (0.125, 0.125, 0.5, 0.125, 0.125)

#: Jacobi 9-pt: C1..C9 row-major over the 3x3 window — corners .05,
#: edges .1, centre .4 (sums to 1).
JACOBI9PT_C = (0.05, 0.1, 0.05, 0.1, 0.4, 0.1, 0.05, 0.1, 0.05)

#: Diffusion-3D: C1..C6 exactly as printed in Table I (six terms:
#: (i,j-1,k), (i-1,j,k), (i,j,k-1), centre, (i+1,j,k), (i,j+1,k)).
#: The printed formula omits (i,j,k+1); we reproduce it verbatim.
DIFFUSION3D_C = (0.1, 0.1, 0.1, 0.5, 0.1, 0.1)

#: Laplace-3D: the printed formula has duplicated neighbours and a 0.25
#: factor (a typo); the standard 6-point Laplace relaxation is intended:
#: mean of the six face neighbours.
LAPLACE3D_C = 1.0 / 6.0

# FLOPs per *interior* cell per iteration, from the Table-I formulas:
#   laplace2d   3 add + 1 mul            =  4
#   diffusion2d 4 add + 5 mul            =  9
#   jacobi9pt   8 add + 9 mul            = 17
#   laplace3d   5 add + 1 mul            =  6
#   diffusion3d 5 add + 6 mul            = 11
FLOPS_PER_CELL: Dict[str, int] = {
    "laplace2d": 4,
    "diffusion2d": 9,
    "jacobi9pt": 17,
    "laplace3d": 6,
    "diffusion3d": 11,
}

#: Halo width (cells) on every side; all Table-I kernels are radius-1.
HALO = 1


def pick_block(n: int, cap: int = 64) -> int:
    """Largest divisor of ``n`` that is <= cap.

    The Pallas grid runs one program per row-block (2D) / plane-block (3D);
    block sizes must divide the axis length.  Worst case (prime n) this
    degenerates to 1-row blocks, which is still correct, just more programs.
    """
    if n <= 0:
        raise ValueError(f"axis length must be positive, got {n}")
    for cand in range(min(cap, n), 0, -1):
        if n % cand == 0:
            return cand
    return 1


@dataclass(frozen=True)
class StencilSpec:
    """Static description of one stencil IP kernel."""

    name: str
    ndim: int
    flops_per_cell: int
    #: tile -> block computation; tile has a 1-cell halo on every side of
    #: every axis, block is the halo-stripped result.
    compute: Callable[[jnp.ndarray], jnp.ndarray] = field(compare=False)


def _boundary_mask(block_shape: Tuple[int, ...],
                   full_shape: Tuple[int, ...],
                   block_offsets: Tuple[jnp.ndarray, ...]) -> jnp.ndarray:
    """True where a cell of this block lies on the *global* grid boundary."""
    mask = jnp.zeros(block_shape, dtype=jnp.bool_)
    for axis, n in enumerate(full_shape):
        idx = jax.lax.broadcasted_iota(jnp.int32, block_shape, axis)
        idx = idx + block_offsets[axis]
        mask = mask | (idx == 0) | (idx == n - 1)
    return mask


def pallas_step(spec: StencilSpec, shape: Tuple[int, ...],
                block_cap: int = 64, interpret: bool = True):
    """Build the single-iteration Pallas function for ``spec`` on ``shape``.

    Returns ``f(x) -> y`` with x, y fp32 arrays of ``shape``.  The function
    pads x by the halo, then launches one program per leading-axis block.
    The *input* is presented to every program as a single full-array block
    (constant index map) and each program slices its halo window with
    ``pl.load`` — Pallas block specs cannot overlap, so the halo exchange
    is expressed as explicit windowed loads (on real TPU this is the
    HBM->VMEM DMA schedule; under interpret=True it is a numpy slice).
    """
    if len(shape) != spec.ndim:
        raise ValueError(f"{spec.name} expects {spec.ndim}D, got {shape}")
    lead = shape[0]
    br = pick_block(lead, block_cap)
    nblocks = lead // br
    padded = tuple(n + 2 * HALO for n in shape)
    trail = shape[1:]

    def kernel(x_ref, o_ref):
        b = pl.program_id(0)
        # Halo-inclusive window for this block: leading axis [b*br, b*br+br+2)
        # of the padded input; full extent of the trailing axes.
        idx = (pl.dslice(b * br, br + 2 * HALO),) + tuple(
            slice(None) for _ in trail
        )
        tile = pl.load(x_ref, idx)
        res = spec.compute(tile)
        centre = tile[tuple(slice(HALO, -HALO) for _ in shape)]
        offs = (b * br,) + tuple(jnp.int32(0) for _ in trail)
        mask = _boundary_mask(res.shape, shape, offs)
        o_ref[...] = jnp.where(mask, centre, res).astype(o_ref.dtype)

    grid = (nblocks,)
    in_spec = pl.BlockSpec(padded, lambda b: tuple(0 for _ in padded))
    out_spec = pl.BlockSpec(
        (br,) + trail, lambda b: (b,) + tuple(0 for _ in trail)
    )
    call = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
        interpret=interpret,
    )

    def step(x):
        x = x.astype(jnp.float32)
        xpad = jnp.pad(x, HALO)  # halo values are masked out; content moot
        return call(xpad)

    return step


# ---------------------------------------------------------------------------
# Registry: kernels register themselves on import (see __init__.py).
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, StencilSpec] = {}


def register(spec: StencilSpec) -> StencilSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"duplicate kernel {spec.name}")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> StencilSpec:
    import compile.kernels  # noqa: F401  (trigger registration)

    return _REGISTRY[name]


def names() -> Sequence[str]:
    import compile.kernels  # noqa: F401

    return tuple(sorted(_REGISTRY))
