"""Table I kernel 1 — Laplace equation, 2-D (4-point, radius 1).

  V'[i,j] = 0.25 * (V[i,j-1] + V[i-1,j] + V[i+1,j] + V[i,j+1])

3 adds + 1 mul = 4 FLOPs per interior cell.
"""

from . import common


def _compute(t):
    # t: (br+2, W+2) halo tile; result: (br, W)
    return 0.25 * (
        t[1:-1, :-2] + t[:-2, 1:-1] + t[2:, 1:-1] + t[1:-1, 2:]
    )


SPEC = common.register(
    common.StencilSpec(
        name="laplace2d", ndim=2,
        flops_per_cell=common.FLOPS_PER_CELL["laplace2d"],
        compute=_compute,
    )
)
