"""Table I kernel 2 — Diffusion, 2-D (5-point weighted, radius 1).

  V'[i,j] = C1*V[i,j-1] + C2*V[i-1,j] + C3*V[i,j] + C4*V[i+1,j] + C5*V[i,j+1]

4 adds + 5 muls = 9 FLOPs per interior cell.
"""

from . import common

C = common.DIFFUSION2D_C


def _compute(t):
    return (
        C[0] * t[1:-1, :-2]
        + C[1] * t[:-2, 1:-1]
        + C[2] * t[1:-1, 1:-1]
        + C[3] * t[2:, 1:-1]
        + C[4] * t[1:-1, 2:]
    )


SPEC = common.register(
    common.StencilSpec(
        name="diffusion2d", ndim=2,
        flops_per_cell=common.FLOPS_PER_CELL["diffusion2d"],
        compute=_compute,
    )
)
