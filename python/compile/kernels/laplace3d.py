"""Table I kernel 4 — Laplace equation, 3-D (6-point, radius 1).

The printed Table-I formula repeats two neighbours and keeps the 2-D 0.25
factor (a typo); the intended 6-point relaxation is the mean of the six
face neighbours:

  V'[i,j,k] = (1/6) * (V[i-1,j,k] + V[i+1,j,k] + V[i,j-1,k]
                       + V[i,j+1,k] + V[i,j,k-1] + V[i,j,k+1])

5 adds + 1 mul = 6 FLOPs per interior cell.
"""

from . import common

C = common.LAPLACE3D_C


def _compute(t):
    c = slice(1, -1)
    return C * (
        t[:-2, c, c] + t[2:, c, c]
        + t[c, :-2, c] + t[c, 2:, c]
        + t[c, c, :-2] + t[c, c, 2:]
    )


SPEC = common.register(
    common.StencilSpec(
        name="laplace3d", ndim=3,
        flops_per_cell=common.FLOPS_PER_CELL["laplace3d"],
        compute=_compute,
    )
)
