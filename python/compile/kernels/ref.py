"""Pure-jnp oracles for the five Table-I stencils.

Deliberately written in a different style from the Pallas kernels (direct
interior-slice assignment on the unpadded grid, no tiling, no masking) so
that agreement between the two is a meaningful correctness signal.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import common

C2 = common.DIFFUSION2D_C
C9 = common.JACOBI9PT_C
C3D = common.DIFFUSION3D_C
CL3 = common.LAPLACE3D_C


def laplace2d(x):
    x = x.astype(jnp.float32)
    interior = 0.25 * (x[1:-1, :-2] + x[:-2, 1:-1] + x[2:, 1:-1] + x[1:-1, 2:])
    return x.at[1:-1, 1:-1].set(interior)


def diffusion2d(x):
    x = x.astype(jnp.float32)
    interior = (
        C2[0] * x[1:-1, :-2]
        + C2[1] * x[:-2, 1:-1]
        + C2[2] * x[1:-1, 1:-1]
        + C2[3] * x[2:, 1:-1]
        + C2[4] * x[1:-1, 2:]
    )
    return x.at[1:-1, 1:-1].set(interior)


def jacobi9pt(x):
    x = x.astype(jnp.float32)
    h, w = x.shape
    acc = jnp.zeros((h - 2, w - 2), jnp.float32)
    k = 0
    for di in (-1, 0, 1):
        for dj in (-1, 0, 1):
            acc = acc + C9[k] * x[1 + di : h - 1 + di, 1 + dj : w - 1 + dj]
            k += 1
    return x.at[1:-1, 1:-1].set(acc)


def laplace3d(x):
    x = x.astype(jnp.float32)
    c = slice(1, -1)
    interior = CL3 * (
        x[:-2, c, c] + x[2:, c, c]
        + x[c, :-2, c] + x[c, 2:, c]
        + x[c, c, :-2] + x[c, c, 2:]
    )
    return x.at[c, c, c].set(interior)


def diffusion3d(x):
    x = x.astype(jnp.float32)
    c = slice(1, -1)
    interior = (
        C3D[0] * x[c, :-2, c]
        + C3D[1] * x[:-2, c, c]
        + C3D[2] * x[c, c, :-2]
        + C3D[3] * x[c, c, c]
        + C3D[4] * x[2:, c, c]
        + C3D[5] * x[c, 2:, c]
    )
    return x.at[c, c, c].set(interior)


REF = {
    "laplace2d": laplace2d,
    "diffusion2d": diffusion2d,
    "jacobi9pt": jacobi9pt,
    "laplace3d": laplace3d,
    "diffusion3d": diffusion3d,
}


def step(name: str, x):
    """Apply one iteration of kernel ``name`` to grid ``x``."""
    return REF[name](x)


def iterate(name: str, x, n: int):
    """Apply ``n`` iterations (what a chain of n pipelined IPs computes)."""
    for _ in range(n):
        x = REF[name](x)
    return x
