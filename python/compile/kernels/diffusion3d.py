"""Table I kernel 5 — Diffusion, 3-D, exactly the six printed terms.

  V'[i,j,k] = C1*V[i,j-1,k] + C2*V[i-1,j,k] + C3*V[i,j,k-1]
              + C4*V[i,j,k]  + C5*V[i+1,j,k] + C6*V[i,j+1,k]

(The printed formula omits the (i,j,k+1) neighbour; reproduced verbatim —
see DESIGN.md.)  5 adds + 6 muls = 11 FLOPs per interior cell.

Axis convention: tile axes are (i, j, k).
"""

from . import common

C = common.DIFFUSION3D_C


def _compute(t):
    c = slice(1, -1)
    return (
        C[0] * t[c, :-2, c]    # V[i, j-1, k]
        + C[1] * t[:-2, c, c]  # V[i-1, j, k]
        + C[2] * t[c, c, :-2]  # V[i, j, k-1]
        + C[3] * t[c, c, c]    # V[i, j, k]
        + C[4] * t[2:, c, c]   # V[i+1, j, k]
        + C[5] * t[c, 2:, c]   # V[i, j+1, k]
    )


SPEC = common.register(
    common.StencilSpec(
        name="diffusion3d", ndim=3,
        flops_per_cell=common.FLOPS_PER_CELL["diffusion3d"],
        compute=_compute,
    )
)
