"""Stencil IP kernels (Pallas, L1) — importing this package registers all
five Table-I kernels in :mod:`compile.kernels.common`."""

from . import common, ref  # noqa: F401
from . import laplace2d, diffusion2d, jacobi9pt, laplace3d, diffusion3d  # noqa: F401

get = common.get
names = common.names
FLOPS_PER_CELL = common.FLOPS_PER_CELL
