"""Table I kernel 3 — Jacobi 9-point, 2-D (full 3x3 window, radius 1).

  V'[i,j] = sum_{di,dj in {-1,0,1}} C[di,dj] * V[i+di, j+dj]

8 adds + 9 muls = 17 FLOPs per interior cell.
"""

from . import common

C = common.JACOBI9PT_C


def _compute(t):
    acc = None
    k = 0
    for di in range(3):  # row offset into the halo tile
        for dj in range(3):
            rows = slice(di, t.shape[0] - 2 + di)
            cols = slice(dj, t.shape[1] - 2 + dj)
            term = C[k] * t[rows, cols]
            acc = term if acc is None else acc + term
            k += 1
    return acc


SPEC = common.register(
    common.StencilSpec(
        name="jacobi9pt", ndim=2,
        flops_per_cell=common.FLOPS_PER_CELL["jacobi9pt"],
        compute=_compute,
    )
)
