"""L2 — the JAX compute graph an IP (or a chain of IPs) executes.

One *step* = one stencil iteration over a full grid = the work one paper IP
performs per pass.  ``chain(spec, shape, k)`` composes k steps — what k
pipelined IPs compute back-to-back; it is AOT-lowered as a fused artifact
for the single-load fast path and used by tests to cross-check the Rust
coordinator's step-by-step execution.

Everything here is build-time only: :mod:`compile.aot` lowers these
functions to HLO text once, and the Rust runtime replays the artifacts.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .kernels import common


def step_fn(name: str, shape: Tuple[int, ...], interpret: bool = True):
    """Single-iteration function for kernel ``name`` on static ``shape``."""
    spec = common.get(name)
    pallas = common.pallas_step(spec, shape, interpret=interpret)

    def step(x):
        # Returned as a 1-tuple: the AOT bridge lowers with
        # return_tuple=True and the Rust side unwraps with to_tuple1().
        return (pallas(x),)

    return step


def chain_fn(name: str, shape: Tuple[int, ...], k: int,
             interpret: bool = True):
    """k fused iterations (a k-IP pipeline segment) as one function."""
    if k < 1:
        raise ValueError(f"chain length must be >= 1, got {k}")
    spec = common.get(name)
    pallas = common.pallas_step(spec, shape, interpret=interpret)

    def chain(x):
        # Unrolled rather than scanned: k is small (<= IPs per FPGA, 4) and
        # unrolling lets XLA fuse across iterations like the physical IP
        # chain does; buffers are donated by the AOT wrapper.
        for _ in range(k):
            x = pallas(x)
        return (x,)

    return chain


@functools.lru_cache(maxsize=None)
def jitted_step(name: str, shape: Tuple[int, ...]):
    return jax.jit(step_fn(name, shape))


# ---------------------------------------------------------------------------
# Table II workload presets (mirrored by rust stencil::workload).
# ---------------------------------------------------------------------------

#: name -> (grid shape, iterations, IPs per FPGA) — Table II of the paper.
TABLE_II = {
    "laplace2d": ((4096, 512), 240, 4),
    "laplace3d": ((512, 64, 64), 240, 2),
    "diffusion2d": ((4096, 512), 240, 1),
    "diffusion3d": ((256, 32, 32), 240, 1),
    "jacobi9pt": ((1024, 128), 240, 1),
}

#: Small shapes used for fast validation artifacts and the quickstart.
SMALL = {
    "laplace2d": (64, 48),
    "diffusion2d": (64, 48),
    "jacobi9pt": (64, 48),
    "laplace3d": (16, 12, 10),
    "diffusion3d": (16, 12, 10),
}
