"""L2 correctness: chained/fused execution == iterated oracle, preset sanity."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import common, ref


def _rand(shape, seed=0):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize("name", sorted(model.TABLE_II))
@settings(max_examples=8, deadline=None)
@given(k=st.integers(1, 6), seed=st.integers(0, 2**32 - 1))
def test_chain_equals_iterated_ref(name, k, seed):
    shape = model.SMALL[name]
    x = _rand(shape, seed)
    chain = model.chain_fn(name, shape, k)
    got = np.asarray(chain(jnp.asarray(x))[0])
    want = np.asarray(ref.iterate(name, jnp.asarray(x), k))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name", sorted(model.TABLE_II))
def test_step_equals_chain1(name):
    shape = model.SMALL[name]
    x = jnp.asarray(_rand(shape, 1))
    s = np.asarray(model.step_fn(name, shape)(x)[0])
    c = np.asarray(model.chain_fn(name, shape, 1)(x)[0])
    np.testing.assert_array_equal(s, c)


def test_jitted_step_cache():
    f1 = model.jitted_step("laplace2d", (8, 8))
    f2 = model.jitted_step("laplace2d", (8, 8))
    assert f1 is f2
    x = jnp.ones((8, 8), jnp.float32)
    np.testing.assert_allclose(np.asarray(f1(x)[0]), np.ones((8, 8)))


def test_chain_rejects_bad_k():
    with pytest.raises(ValueError):
        model.chain_fn("laplace2d", (8, 8), 0)


def test_table_ii_presets():
    # Mirrors the paper's Table II; the Rust side hardcodes the same values
    # (stencil::workload) and the figures depend on them.
    assert model.TABLE_II["laplace2d"] == ((4096, 512), 240, 4)
    assert model.TABLE_II["laplace3d"] == ((512, 64, 64), 240, 2)
    assert model.TABLE_II["diffusion2d"] == ((4096, 512), 240, 1)
    assert model.TABLE_II["diffusion3d"] == ((256, 32, 32), 240, 1)
    assert model.TABLE_II["jacobi9pt"] == ((1024, 128), 240, 1)
    for name, (shape, iters, ips) in model.TABLE_II.items():
        assert iters == 240
        assert common.get(name).ndim == len(shape)
        assert 1 <= ips <= 4


def test_small_shapes_have_interior():
    for name, shape in model.SMALL.items():
        assert all(d >= 3 for d in shape)
        assert common.get(name).ndim == len(shape)
