"""AOT bridge tests: HLO-text structure, manifest integrity, and that the
shipped artifact set covers everything the Rust coordinator needs."""

import json
import os

import pytest

from compile import aot, model


def test_lower_small_step_structure():
    text = aot.lower_fn(model.step_fn("laplace2d", (8, 6)), (8, 6))
    # Text interchange invariants the Rust loader relies on:
    assert text.startswith("HloModule")
    assert "f32[8,6]" in text                       # entry shape
    assert "->(f32[8,6]" in text                    # tuple return (1-tuple)
    # Donated input buffer lowered to an input/output alias:
    assert "input_output_alias" in text


def test_lower_is_deterministic():
    f = lambda: aot.lower_fn(model.step_fn("diffusion2d", (8, 6)), (8, 6))
    assert f() == f()


def test_artifact_list_covers_table_ii():
    arts = aot.artifact_list()
    names = {aot.art_name(a) for a in arts}
    assert len(names) == len(arts), "artifact names must be unique"
    for kernel, (shape, _iters, ips) in model.TABLE_II.items():
        s = "x".join(map(str, shape))
        assert f"{kernel}_paper_{s}" in names
        if ips > 1:
            assert f"{kernel}_paper_{s}_chain{ips}" in names
    for kernel, shape in model.SMALL.items():
        s = "x".join(map(str, shape))
        assert f"{kernel}_small_{s}" in names
        assert f"{kernel}_small_{s}_chain4" in names


def test_build_into_tmpdir(tmp_path):
    aot.build(str(tmp_path), only="laplace2d_small")
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == 1
    assert manifest["interchange"] == "hlo-text"
    entries = manifest["artifacts"]
    assert {e["name"] for e in entries} == {
        "laplace2d_small_64x48", "laplace2d_small_64x48_chain4"
    }
    for e in entries:
        p = tmp_path / e["file"]
        assert p.exists()
        text = p.read_text()
        assert text.startswith("HloModule")
        assert e["flops_per_cell"] == 4
        assert e["dtype"] == "f32"


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "manifest.json")),
    reason="run `make artifacts` first",
)
def test_shipped_manifest_consistent():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = json.load(open(os.path.join(root, "manifest.json")))
    assert len(manifest["artifacts"]) == len(aot.artifact_list())
    for e in manifest["artifacts"]:
        assert os.path.exists(os.path.join(root, e["file"])), e["name"]
