"""L1 correctness: every Pallas kernel == ref.py oracle (the CORE signal),
plus independent numpy-float64 checks and stencil invariants.

Hypothesis sweeps shapes (including primes and minimal grids) and value
regimes; each Pallas call rebuilds the row-block schedule for that shape,
so the block/halo indexing is exercised across block sizes 1..64.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import common, ref
from compile import model

KERNELS_2D = ["laplace2d", "diffusion2d", "jacobi9pt"]
KERNELS_3D = ["laplace3d", "diffusion3d"]
ALL = KERNELS_2D + KERNELS_3D

# shapes >= 3 per axis so an interior exists; include primes (block=1 path)
DIM_2D = st.tuples(st.integers(3, 97), st.integers(3, 33))
DIM_3D = st.tuples(st.integers(3, 17), st.integers(3, 13), st.integers(3, 11))


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (scale * rng.standard_normal(shape)).astype(np.float32)


def _run_pallas(name, x):
    spec = common.get(name)
    f = common.pallas_step(spec, x.shape)
    return np.asarray(f(jnp.asarray(x)))


# ---------------------------------------------------------------------------
# Pallas vs ref oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", KERNELS_2D)
@settings(max_examples=25, deadline=None)
@given(shape=DIM_2D, seed=st.integers(0, 2**32 - 1))
def test_pallas_matches_ref_2d(name, shape, seed):
    x = _rand(shape, seed)
    got = _run_pallas(name, x)
    want = np.asarray(ref.step(name, jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", KERNELS_3D)
@settings(max_examples=15, deadline=None)
@given(shape=DIM_3D, seed=st.integers(0, 2**32 - 1))
def test_pallas_matches_ref_3d(name, shape, seed):
    x = _rand(shape, seed)
    got = _run_pallas(name, x)
    want = np.asarray(ref.step(name, jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("name", ALL)
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1),
       scale=st.sampled_from([1e-3, 1.0, 1e3]))
def test_pallas_matches_ref_value_regimes(name, seed, scale):
    shape = model.SMALL[name]
    x = _rand(shape, seed, scale)
    got = _run_pallas(name, x)
    want = np.asarray(ref.step(name, jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6 * scale)


# ---------------------------------------------------------------------------
# Independent numpy-float64 oracles (catches a shared jnp mistake)
# ---------------------------------------------------------------------------

def _np64_step(name, x):
    x = x.astype(np.float64)
    out = x.copy()
    if name == "laplace2d":
        out[1:-1, 1:-1] = 0.25 * (
            x[1:-1, :-2] + x[:-2, 1:-1] + x[2:, 1:-1] + x[1:-1, 2:]
        )
    elif name == "diffusion2d":
        c = common.DIFFUSION2D_C
        out[1:-1, 1:-1] = (
            c[0] * x[1:-1, :-2] + c[1] * x[:-2, 1:-1] + c[2] * x[1:-1, 1:-1]
            + c[3] * x[2:, 1:-1] + c[4] * x[1:-1, 2:]
        )
    elif name == "jacobi9pt":
        c = common.JACOBI9PT_C
        acc = np.zeros((x.shape[0] - 2, x.shape[1] - 2))
        k = 0
        for di in (-1, 0, 1):
            for dj in (-1, 0, 1):
                acc += c[k] * x[1 + di:x.shape[0] - 1 + di,
                                1 + dj:x.shape[1] - 1 + dj]
                k += 1
        out[1:-1, 1:-1] = acc
    elif name == "laplace3d":
        s = slice(1, -1)
        out[s, s, s] = (1.0 / 6.0) * (
            x[:-2, s, s] + x[2:, s, s] + x[s, :-2, s]
            + x[s, 2:, s] + x[s, s, :-2] + x[s, s, 2:]
        )
    elif name == "diffusion3d":
        c = common.DIFFUSION3D_C
        s = slice(1, -1)
        out[s, s, s] = (
            c[0] * x[s, :-2, s] + c[1] * x[:-2, s, s] + c[2] * x[s, s, :-2]
            + c[3] * x[s, s, s] + c[4] * x[2:, s, s] + c[5] * x[s, 2:, s]
        )
    else:
        raise KeyError(name)
    return out


@pytest.mark.parametrize("name", ALL)
def test_pallas_matches_numpy_float64(name):
    x = _rand(model.SMALL[name], seed=7)
    got = _run_pallas(name, x)
    want = _np64_step(name, x)
    # fp32 kernel vs fp64 oracle: tolerance is fp32 rounding of ~17 terms
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Stencil invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALL)
def test_boundary_cells_copy_through(name):
    x = _rand(model.SMALL[name], seed=11)
    got = _run_pallas(name, x)
    if x.ndim == 2:
        for sl in (np.s_[0, :], np.s_[-1, :], np.s_[:, 0], np.s_[:, -1]):
            np.testing.assert_array_equal(got[sl], x[sl])
    else:
        for ax in range(3):
            for edge in (0, -1):
                sl = [slice(None)] * 3
                sl[ax] = edge
                np.testing.assert_array_equal(got[tuple(sl)], x[tuple(sl)])


@pytest.mark.parametrize("name", ALL)
def test_constant_grid_is_fixed_point(name):
    # All coefficient sets sum to 1 (laplace: 4*0.25, 6*(1/6)), except
    # diffusion3d whose printed Table-I formula sums to 1 as configured.
    x = np.full(model.SMALL[name], 3.25, np.float32)
    got = _run_pallas(name, x)
    np.testing.assert_allclose(got, x, rtol=1e-6)


@pytest.mark.parametrize("name", ALL)
def test_linearity(name):
    # Every Table-I kernel is a linear operator: f(ax+by) = a f(x) + b f(y)
    shape = model.SMALL[name]
    x, y = _rand(shape, 1), _rand(shape, 2)
    a, b = np.float32(0.5), np.float32(-2.0)
    lhs = _run_pallas(name, a * x + b * y)
    rhs = a * _run_pallas(name, x) + b * _run_pallas(name, y)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ALL)
def test_locality_radius_one(name):
    # Perturbing one interior cell changes only the radius-1 neighbourhood.
    shape = model.SMALL[name]
    x = _rand(shape, 3)
    centre = tuple(d // 2 for d in shape)
    x2 = x.copy()
    x2[centre] += 1.0
    d = np.abs(_run_pallas(name, x2) - _run_pallas(name, x))
    changed = np.argwhere(d > 0)
    assert len(changed) > 0
    for idx in changed:
        assert max(abs(int(i) - int(c)) for i, c in zip(idx, centre)) <= 1


def test_flops_table_matches_registry():
    for name in common.names():
        assert common.get(name).flops_per_cell == common.FLOPS_PER_CELL[name]


def test_pick_block():
    assert common.pick_block(4096) == 64
    assert common.pick_block(97) == 1          # prime
    assert common.pick_block(48) == 48
    assert common.pick_block(130, cap=64) == 26
    with pytest.raises(ValueError):
        common.pick_block(0)
