"""Tests for the L2 HLO inspection tool (the §Perf analysis surface)."""

import pytest

from compile import aot, model
from compile.inspect_hlo import analyze_text


@pytest.fixture(scope="module")
def small_step_text():
    return aot.lower_fn(model.step_fn("laplace2d", (8, 6)), (8, 6))


def test_op_histogram_sane(small_step_text):
    a = analyze_text(small_step_text)
    assert a["total_ops"] > 5
    # a stencil step must contain adds/multiplies somewhere (possibly
    # inside fusions) and a pad for the halo
    assert "pad" in a["ops"] or a["fusions"] > 0
    assert a["aliased_io"], "donated input must lower to an io alias"


def test_buffer_footprint_scales_with_shape():
    small = analyze_text(aot.lower_fn(model.step_fn("laplace2d", (8, 6)), (8, 6)))
    big = analyze_text(
        aot.lower_fn(model.step_fn("laplace2d", (64, 48)), (64, 48))
    )
    assert big["max_buffer_mib"] > small["max_buffer_mib"]


def test_chain_has_no_duplicate_recompute():
    # a fused k-chain must scale op count ~linearly in k, not
    # quadratically (no recompute of earlier iterations)
    t1 = analyze_text(aot.lower_fn(model.step_fn("diffusion2d", (8, 6)), (8, 6)))
    t4 = analyze_text(
        aot.lower_fn(model.chain_fn("diffusion2d", (8, 6), 4), (8, 6))
    )
    assert t4["total_ops"] <= 4.6 * t1["total_ops"], (
        t1["total_ops"],
        t4["total_ops"],
    )


def test_vmem_budget_paper_shapes():
    # DESIGN.md §8: per-program *block* footprint is what must fit VMEM
    # on a real TPU; under interpret=True the whole padded grid is staged
    # (single-block input spec), so the static proxy here is the staged
    # footprint — bounded, and dominated by the grid itself (< 64 MiB,
    # i.e. HBM-resident with row-blocks DMA'd per program)
    text = aot.lower_fn(
        model.step_fn("laplace2d", (4096, 512)), (4096, 512)
    )
    a = analyze_text(text)
    assert 8.0 < a["max_buffer_mib"] < 64.0
