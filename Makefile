# Entry points the docs and test skip-messages refer to.

.PHONY: artifacts test perf warm-start failover serving sharded clean

# AOT-lower the five Table-I stencils to HLO-text artifacts + manifest.
# Written to ./artifacts (where the examples, run from the repo root,
# look) and symlinked at rust/artifacts (where `cargo test`, whose cwd
# is the rust/ package root, looks) so every consumer agrees.
artifacts:
	cd python && python -m compile.aot --out ../artifacts
	ln -sfn ../artifacts rust/artifacts

# Tier-1 verification.
test:
	cargo build --release
	cargo test -q

# The BENCH harness: hot-path timings -> BENCH_perf.json at the repo
# root (schema: name -> {median_s, throughput, ...}; DESIGN.md §7).
perf:
	cargo bench --bench perf

# Executable persistence round-trip: compile + save a plan, then load
# it into a fresh runtime and serve with zero compiles (DESIGN.md §8).
# Leaves results/served_stencil.plan.json behind for inspection.
warm-start:
	cargo run --release --example served_stencil

# Mid-run board-death recovery demo: a serving process loses a board
# (then the survivor), stays bit-identical, and writes the itemized
# recovery bill to results/failover_recovery.json (DESIGN.md §9).
failover:
	cargo run --release --example failover

# Multi-tenant serving demo: four tenants (coalesced plans, WFQ,
# admission control, one resident working set) ride through a mid-run
# board death with bit-identical grids (DESIGN.md §10).
serving:
	cargo run --release --example multi_tenant_serving

# Cluster-wide grid sharding demo: a grid too large for any one board
# runs row-sharded across 2/4/6 VC709s with halo-exchange tasks, stays
# bit-identical to the host reference, shows makespan improving
# monotonically with boards and ring-vs-crossbar fabric pricing, then
# sweeps the §12 communication-avoidance knobs ({block, split}:
# temporal halo blocking cuts exchanges and makespan, interior/boundary
# splitting drops the halo-blocked seconds) and writes everything to
# results/shard_scaling.json (DESIGN.md §11–§12).
sharded:
	cargo run --release --example sharded_stencil
	cargo bench --bench shard

clean:
	rm -rf target artifacts rust/artifacts results BENCH_*.json
	find . -name '*.plan.json' -delete
