# Entry points the docs and test skip-messages refer to.

.PHONY: artifacts test clean

# AOT-lower the five Table-I stencils to HLO-text artifacts + manifest.
# Written to ./artifacts (where the examples, run from the repo root,
# look) and symlinked at rust/artifacts (where `cargo test`, whose cwd
# is the rust/ package root, looks) so every consumer agrees.
artifacts:
	cd python && python -m compile.aot --out ../artifacts
	ln -sfn ../artifacts rust/artifacts

# Tier-1 verification.
test:
	cargo build --release
	cargo test -q

clean:
	rm -rf target artifacts rust/artifacts results
