# Entry points the docs and test skip-messages refer to.

.PHONY: artifacts test perf clean

# AOT-lower the five Table-I stencils to HLO-text artifacts + manifest.
# Written to ./artifacts (where the examples, run from the repo root,
# look) and symlinked at rust/artifacts (where `cargo test`, whose cwd
# is the rust/ package root, looks) so every consumer agrees.
artifacts:
	cd python && python -m compile.aot --out ../artifacts
	ln -sfn ../artifacts rust/artifacts

# Tier-1 verification.
test:
	cargo build --release
	cargo test -q

# The BENCH harness: hot-path timings -> BENCH_perf.json at the repo
# root (schema: name -> {median_s, throughput, ...}; DESIGN.md §7).
perf:
	cargo bench --bench perf

clean:
	rm -rf target artifacts rust/artifacts results BENCH_*.json
