//! Resource report: regenerates Table III and Figure 10 from the
//! synthesis estimator, and answers the paper's §V-C question — how many
//! IPs *could* fit per board, area-wise, for each kernel (the headroom
//! the paper says a better design flow would unlock).
//!
//! ```sh
//! cargo run --release --example resource_report
//! ```

use omp_fpga::figures::tables;
use omp_fpga::hw::resources;
use omp_fpga::stencil::kernels::ALL_KERNELS;
use omp_fpga::stencil::workload::paper_workload;

fn main() {
    for block in [
        tables::table1(),
        tables::table2(),
        tables::table3(),
        tables::fig10(),
    ] {
        for line in block {
            println!("{line}");
        }
        println!();
    }

    println!("== area headroom (paper §V-C: \"plenty of hardware to be used\") ==");
    println!(
        "{:<18} {:>10} {:>12} {:>16}",
        "kernel", "Table-II", "area-max", "binding resource"
    );
    for k in ALL_KERNELS {
        let w = paper_workload(k);
        let free = resources::free_region();
        let one = resources::ip_resources(k, &w.shape);
        let max_ips = [
            free.luts / one.luts.max(1),
            free.bram36 / one.bram36.max(1),
            free.dsp / one.dsp.max(1),
        ]
        .into_iter()
        .min()
        .unwrap();
        let binding = if max_ips == free.luts / one.luts.max(1) {
            "LUTs"
        } else if max_ips == free.bram36 / one.bram36.max(1) {
            "BRAM"
        } else {
            "DSP"
        };
        println!(
            "{:<18} {:>10} {:>12} {:>16}",
            k.paper_name(),
            w.ips_per_fpga,
            max_ips,
            binding
        );
        assert!(
            resources::fits(k, &w.shape, w.ips_per_fpga),
            "Table-II configuration must fit"
        );
    }
    println!(
        "\nthe Table-II IP counts were limited by Vivado timing closure, \
         not area — consistent with the paper's §V-C discussion"
    );
}
