//! Device-resident iterative stencil: OpenMP 4.5 `target data` over the
//! VC709 cluster.
//!
//! The paper's runtime elides host round-trips *inside* one batch
//! (§III-A); this example shows the across-batch generalization.  Eight
//! Jacobi-style sweeps run over one grid, each sweep split into its own
//! FPGA batch by a host monitor task — so without a data region the
//! grid re-streams over PCIe every sweep.  Wrapping the loop in
//! `target_data` keeps the grid parked in device memory: one H2D on the
//! first sweep, one bulk writeback at region exit, and a strictly lower
//! modelled makespan with bit-identical numerics.
//!
//! ```sh
//! cargo run --release --example resident_stencil
//! ```

use anyhow::Result;

use omp_fpga::config::ClusterConfig;
use omp_fpga::omp::{DataEnv, DeviceId, MapDir, OmpRuntime};
use omp_fpga::plugin::{ExecBackend, Vc709Plugin};
use omp_fpga::stencil::{Grid, Kernel};

const SWEEPS: usize = 8;

fn build_runtime(kernel: Kernel) -> Result<(OmpRuntime, DeviceId)> {
    let mut rt = OmpRuntime::new(2);
    rt.declare_hw_variant("do_step", "vc709", "hw_step", kernel);
    rt.register_software("monitor", |env| {
        let mut r = env.take("R")?;
        for v in r.data_mut() {
            *v += 1.0; // the residual-check stand-in
        }
        env.put("R", r);
        Ok(())
    });
    let cfg = ClusterConfig::homogeneous(1, 2, kernel);
    let fpga = rt
        .register_device(Box::new(Vc709Plugin::new(&cfg, ExecBackend::Golden)?));
    Ok((rt, fpga))
}

fn sweeps(
    rt: &mut OmpRuntime,
    env: &mut DataEnv,
    fpga: DeviceId,
) -> Result<f64> {
    let deps = rt.dep_vars(3 * SWEEPS + 2);
    let report = rt.parallel(env, |ctx| {
        for s in 0..SWEEPS {
            for i in 0..2 {
                ctx.target("do_step")
                    .device(fpga)
                    .map(MapDir::ToFrom, "V")
                    .depend_in(deps[3 * s + i])
                    .depend_out(deps[3 * s + i + 1])
                    .nowait()
                    .submit()?;
            }
            ctx.task("monitor")
                .map(MapDir::ToFrom, "R")
                .depend_in(deps[3 * s + 2])
                .depend_out(deps[3 * s + 3])
                .nowait()
                .submit()?;
        }
        Ok(())
    })?;
    let elided: usize =
        report.batches.iter().map(|(_, r)| r.stats.h2d_elided).sum();
    println!(
        "  {} batches, {} H2D elided, makespan {:.6} s",
        report.batches.len(),
        elided,
        report.virtual_time_s()
    );
    Ok(report.virtual_time_s())
}

fn main() -> Result<()> {
    let kernel = Kernel::Diffusion2d;
    let input = Grid::random(&[48, 20], 5)?;

    // per-sweep streaming: every FPGA batch pays the PCIe round-trip
    println!("per-sweep streaming:");
    let (mut rt, fpga) = build_runtime(kernel)?;
    let mut env = DataEnv::new();
    env.insert("V", input.clone());
    env.insert("R", Grid::zeros(&[1, 1])?);
    let t_stream = sweeps(&mut rt, &mut env, fpga)?;
    let v_stream = env.take("V")?;

    // device-resident: one H2D, sweeps run out of device memory, one
    // bulk writeback at region exit
    println!("target data region:");
    let (mut rt, fpga) = build_runtime(kernel)?;
    let mut env = DataEnv::new();
    env.insert("V", input.clone());
    env.insert("R", Grid::zeros(&[1, 1])?);
    let (makespan, wb) = rt.target_data(fpga, &mut env, &["V"], |rt, env| {
        sweeps(rt, env, fpga)
    })?;
    let t_res = makespan + wb;
    let v_res = env.take("V")?;
    println!("  exit writeback {wb:.6} s");

    println!(
        "resident {t_res:.6} s vs streaming {t_stream:.6} s \
         ({:.2}x faster over {SWEEPS} sweeps)",
        t_stream / t_res
    );
    anyhow::ensure!(t_res < t_stream, "residency must win");
    anyhow::ensure!(v_res == v_stream, "numerics must be bit-identical");
    anyhow::ensure!(rt.present().is_empty(), "region must drain");
    println!("resident stencil OK");
    Ok(())
}
