//! Mid-run device failure and recovery over the VC709 cluster — the
//! platform's unhappy paths, end to end (DESIGN.md §9).
//!
//! A serving process replays one compiled stencil plan per request on a
//! two-board cluster.  Mid-service a board dies on dispatch (injected
//! via the deterministic fault plane, exactly as the property net does
//! it): the executor observes the typed `DeviceFailed`, marks the board
//! dead with a named epoch bump, invalidates its residency, re-places
//! the orphaned run on the survivor through the same `device(any)` HEFT
//! pricing that compiled the plan, and drains the recovery schedule —
//! grids stay **bit-identical** to a failure-free service because
//! functional truth never leaves the host data environment.  The stale
//! executable is then refused *by name*, the runtime recompiles on the
//! surviving board, and when the survivor is hot-removed too the same
//! region degrades to the host base function — still bit-identical.
//!
//! The itemized recovery bill is written to
//! `results/failover_recovery.json` (uploaded by CI's fault-smoke job).
//!
//! ```sh
//! cargo run --release --example failover   # or: make failover
//! ```

use anyhow::{ensure, Result};

use omp_fpga::config::ClusterConfig;
use omp_fpga::omp::{
    DataEnv, DepVar, DeviceId, FaultSchedule, MapDir, OmpRuntime,
    RecoveryEvent, SingleCtx,
};
use omp_fpga::plugin::{ExecBackend, Vc709Plugin};
use omp_fpga::stencil::{Grid, Kernel};

const REQUESTS: usize = 6;
const STEPS: usize = 4;
/// the request whose only batch observes the injected board death
const FAIL_AT_REQUEST: usize = 3;

fn build_runtime(kernel: Kernel) -> Result<OmpRuntime> {
    let mut rt = OmpRuntime::new(2);
    // the software base function is the degradation tier: same
    // reference numerics the Golden backend runs, so host fallback is
    // bit-identical by construction
    rt.register_software("do_step", move |env| {
        let g = env.take("V")?;
        env.insert("V", kernel.apply(&g)?);
        Ok(())
    });
    rt.declare_hw_variant("do_step", "vc709", "hw_step", kernel);
    let cfg = ClusterConfig::homogeneous(1, 2, kernel);
    for _ in 0..2 {
        rt.register_device(Box::new(Vc709Plugin::new(
            &cfg,
            ExecBackend::Golden,
        )?));
    }
    Ok(rt)
}

fn submit_request(ctx: &mut SingleCtx, deps: &[DepVar]) -> Result<()> {
    for i in 0..STEPS {
        ctx.target("do_step")
            .device_any()
            .map(MapDir::ToFrom, "V")
            .depend_in(deps[i])
            .depend_out(deps[i + 1])
            .nowait()
            .submit()?;
    }
    Ok(())
}

fn serve_one(rt: &mut OmpRuntime, env: &mut DataEnv) -> Result<f64> {
    let deps = rt.dep_vars(STEPS + 1);
    let rep = rt.parallel(env, |ctx| submit_request(ctx, &deps))?;
    Ok(rep.virtual_time_s())
}

fn main() -> Result<()> {
    let kernel = Kernel::Diffusion2d;
    let input = Grid::random(&[48, 32], 7)?;

    // -- reference: the same service with no failures, ever ------------
    let mut ref_rt = build_runtime(kernel)?;
    let mut ref_env = DataEnv::new();
    ref_env.insert("V", input.clone());
    for _ in 0..REQUESTS {
        serve_one(&mut ref_rt, &mut ref_env)?;
    }

    // -- the failing service -------------------------------------------
    let mut rt = build_runtime(kernel)?;
    let mut env = DataEnv::new();
    env.insert("V", input.clone());
    let deps = rt.dep_vars(STEPS + 1);
    let exe = rt
        .capture(&env, |ctx| submit_request(ctx, &deps))?
        .compile(&mut rt)?;
    // request 0 through `parallel` (priming the plan cache — its stale
    // entry is what gets the named recompile after the failure), the
    // rest through the compiled executable
    serve_one(&mut rt, &mut env)?;
    for _ in 1..FAIL_AT_REQUEST {
        exe.execute(&mut rt, &mut env)?;
    }

    // board 1 (which the HEFT tie-break owns this chain on) dies on its
    // next dispatch; deterministic, so this run always reproduces
    rt.inject_faults(FaultSchedule::new().fail_after_batches(DeviceId(1), 0))?;
    let rep = exe.execute(&mut rt, &mut env)?;
    println!("request {FAIL_AT_REQUEST} observed a board death:");
    for ev in &rep.recovery {
        println!("  {ev:?}");
    }
    println!("  bill: {:?}", rep.recovery_cost);
    ensure!(rep.recovery_cost.failures == 1, "exactly one board died");
    ensure!(
        rep.recovery.iter().any(|e| matches!(
            e,
            RecoveryEvent::RunReplaced { to, .. } if *to == DeviceId(2)
        )),
        "the orphaned run must re-place on the survivor"
    );
    ensure!(rt.is_dead(DeviceId(1)), "the dead board stays dead");

    // the committed plan referenced the dead board: refused by name
    let err = exe.execute(&mut rt, &mut env).unwrap_err();
    println!("stale plan    : {err:#}");
    ensure!(format!("{err:#}").contains("device_failed"), "{err:#}");

    // service continues on the survivor — `parallel` recompiles, by name
    for _ in FAIL_AT_REQUEST + 1..REQUESTS {
        serve_one(&mut rt, &mut env)?;
    }
    ensure!(
        rt.plan_stats()
            .recompiles
            .iter()
            .any(|r| r.contains("device_failed")),
        "the recompile must be attributed to the death"
    );
    ensure!(
        env.get("V")? == ref_env.get("V")?,
        "recovered service diverged from the failure-free reference"
    );
    println!(
        "served {REQUESTS} requests across the failure — grids \
         bit-identical to the failure-free service"
    );

    // -- lose the survivor too: degrade to the host base function ------
    rt.unregister_device(DeviceId(2))?;
    let t_host = serve_one(&mut rt, &mut env)?;
    serve_one(&mut ref_rt, &mut ref_env)?;
    ensure!(
        env.get("V")? == ref_env.get("V")?,
        "host-degraded request diverged"
    );
    ensure!(t_host == 0.0, "host base functions are free in virtual time");
    println!(
        "survivor hot-removed: request {} degraded to the host base \
         function — still bit-identical",
        REQUESTS
    );

    // -- the itemized bill, for CI -------------------------------------
    std::fs::create_dir_all("results")?;
    let cost = &rep.recovery_cost;
    let json = format!(
        "{{\n  \"failures\": {},\n  \"extra_makespan_s\": {},\n  \
         \"replacements\": {},\n  \"host_fallbacks\": {},\n  \
         \"restreamed_bytes\": {},\n  \"recovery_events\": {}\n}}\n",
        cost.failures,
        cost.extra_makespan_s,
        cost.replacements,
        cost.host_fallbacks,
        cost.restreamed_bytes,
        rep.recovery.len()
    );
    std::fs::write("results/failover_recovery.json", json)?;
    println!("wrote results/failover_recovery.json");
    Ok(())
}
