//! Heterogeneous pipeline: CPU tasks and FPGA tasks in ONE dependence
//! graph — the paper's third contribution ("a single programming model to
//! run its application on a truly heterogeneous architecture").
//!
//! The program: host pre-processing (scale the grid), a 12-iteration
//! Diffusion-2D pipeline on a 3-board FPGA cluster, then host
//! post-processing (accumulate a residual) — all expressed as OpenMP
//! tasks with depend clauses; the runtime splits the graph into host and
//! vc709 batches automatically.
//!
//! ```sh
//! make artifacts && cargo run --release --example heterogeneous
//! ```

use anyhow::{Context, Result};

use omp_fpga::config::ClusterConfig;
use omp_fpga::omp::{DataEnv, MapDir, OmpRuntime};
use omp_fpga::plugin::{ExecBackend, Vc709Plugin};
use omp_fpga::stencil::{Grid, Kernel};

const FPGA_ITERS: usize = 12;

fn main() -> Result<()> {
    let kernel = Kernel::Diffusion2d;
    let shape = [64usize, 48];

    let mut rt = OmpRuntime::new(4);
    // host tasks
    rt.register_software("preprocess", |env| {
        let mut g = env.take("V")?;
        for v in g.data_mut() {
            *v *= 0.5; // normalize input
        }
        env.put("V", g);
        Ok(())
    });
    rt.register_software("postprocess", |env| {
        let g = env.take("V")?;
        let (sum, l2) = g.checksum();
        println!("host post-processing: sum={sum:.4} l2={l2:.4}");
        env.put("V", g);
        Ok(())
    });
    // FPGA task (declare variant)
    rt.register_software("do_diffusion2d", move |env| {
        let g = env.take("V")?;
        env.put("V", kernel.apply(&g)?);
        Ok(())
    });
    rt.declare_hw_variant("do_diffusion2d", "vc709", "hw_diffusion2d", kernel);

    let cfg = ClusterConfig::homogeneous(3, 1, kernel);
    let fpga = rt.register_device(Box::new(
        Vc709Plugin::new(&cfg, ExecBackend::Pjrt)
            .context("run `make artifacts` first")?,
    ));

    let input = Grid::random(&shape, 11)?;
    let mut env = DataEnv::new();
    env.insert("V", input.clone());
    let deps = rt.dep_vars(FPGA_ITERS + 3);

    let report = rt.parallel(&mut env, |ctx| {
        // host pre-processing task
        ctx.task("preprocess")
            .map(MapDir::ToFrom, "V")
            .depend_out(deps[0])
            .nowait()
            .submit()?;
        // FPGA pipeline (device clause selects the vc709 plugin)
        for i in 0..FPGA_ITERS {
            ctx.target("do_diffusion2d")
                .device(fpga)
                .map(MapDir::ToFrom, "V")
                .depend_in(deps[i])
                .depend_out(deps[i + 1])
                .nowait()
                .submit()?;
        }
        // host post-processing task
        ctx.task("postprocess")
            .map(MapDir::ToFrom, "V")
            .depend_in(deps[FPGA_ITERS])
            .depend_out(deps[FPGA_ITERS + 1])
            .nowait()
            .submit()?;
        Ok(())
    })?;

    // the runtime must have split the graph host -> vc709 -> host
    println!(
        "device batches: {:?}",
        report
            .batches
            .iter()
            .map(|(d, r)| format!("device{}:{} tasks", d.0, r.tasks_run))
            .collect::<Vec<_>>()
    );
    anyhow::ensure!(report.batches.len() == 3, "expected 3 device batches");

    // verify against the all-software composition
    let mut expected = input.clone();
    for v in expected.data_mut() {
        *v *= 0.5;
    }
    let expected = kernel.iterate(&expected, FPGA_ITERS)?;
    let got = env.take("V")?;
    let diff = got.max_abs_diff(&expected);
    println!("heterogeneous pipeline vs software max|Δ| = {diff:.3e}");
    anyhow::ensure!(diff < 1e-4, "verification failed");
    println!("heterogeneous OK");
    Ok(())
}
