//! Heterogeneous *interleaved* pipeline: CPU tasks and FPGA tasks in ONE
//! dependence graph — the paper's third contribution ("a single
//! programming model to run its application on a truly heterogeneous
//! architecture") — in a shape the old batch executor rejected outright:
//!
//! ```text
//! host preprocess -> FPGA chain -> host renormalize -> FPGA chain -> host post
//! ```
//!
//! New in this revision: the FPGA stages are submitted with
//! `device(any)` instead of a hand-picked device id.  TWO vc709
//! clusters are registered — a 3-board ring and a single board — and
//! the scheduler's communication-aware placer (DESIGN.md §3) prices
//! each unbound chain on both clusters and commits the earliest
//! modelled finish: the 3-board ring wins (6 tasks in 2 passes instead
//! of 6), with no placement code in the application.
//!
//! ```sh
//! cargo run --release --example heterogeneous
//! # uses the PJRT artifacts when present (make artifacts), golden model otherwise
//! ```

use anyhow::Result;

use omp_fpga::config::ClusterConfig;
use omp_fpga::omp::{DataEnv, MapDir, OmpRuntime};
use omp_fpga::plugin::{ExecBackend, Vc709Plugin};
use omp_fpga::stencil::{Grid, Kernel};

/// FPGA iterations per pipeline stage (two stages total).
const STAGE_ITERS: usize = 6;

fn main() -> Result<()> {
    let kernel = Kernel::Diffusion2d;
    let shape = [64usize, 48];

    let mut rt = OmpRuntime::new(4);
    // host tasks
    rt.register_software("preprocess", |env| {
        let mut g = env.take("V")?;
        for v in g.data_mut() {
            *v *= 0.5; // normalize input
        }
        env.put("V", g);
        Ok(())
    });
    rt.register_software("renormalize", |env| {
        let mut g = env.take("V")?;
        for v in g.data_mut() {
            *v *= 2.0; // mid-pipeline host stage between the FPGA chains
        }
        env.put("V", g);
        Ok(())
    });
    rt.register_software("postprocess", |env| {
        let g = env.take("V")?;
        let (sum, l2) = g.checksum();
        println!("host post-processing: sum={sum:.4} l2={l2:.4}");
        env.put("V", g);
        Ok(())
    });
    // FPGA task (declare variant); the base body doubles as the host
    // fallback the placer would use if no cluster carried the kernel
    rt.register_software("do_diffusion2d", move |env| {
        let g = env.take("V")?;
        env.put("V", kernel.apply(&g)?);
        Ok(())
    });
    rt.declare_hw_variant("do_diffusion2d", "vc709", "hw_diffusion2d", kernel);

    let backend = if omp_fpga::runtime::artifacts_present("artifacts") {
        ExecBackend::Pjrt
    } else {
        ExecBackend::Golden // no artifacts: fall back to the golden model
    };
    // two clusters of different sizes — the placer must prefer the ring
    let big = rt.register_device(Box::new(Vc709Plugin::new(
        &ClusterConfig::homogeneous(3, 1, kernel),
        backend,
    )?));
    let small = rt.register_device(Box::new(Vc709Plugin::new(
        &ClusterConfig::homogeneous(1, 1, kernel),
        backend,
    )?));

    let input = Grid::random(&shape, 11)?;
    let mut env = DataEnv::new();
    env.insert("V", input.clone());
    let deps = rt.dep_vars(2 * STAGE_ITERS + 4);

    let report = rt.parallel(&mut env, |ctx| {
        // host pre-processing task
        ctx.task("preprocess")
            .map(MapDir::ToFrom, "V")
            .depend_out(deps[0])
            .nowait()
            .submit()?;
        // first FPGA pipeline — device(any): the scheduler places it
        for i in 0..STAGE_ITERS {
            ctx.target("do_diffusion2d")
                .device_any()
                .map(MapDir::ToFrom, "V")
                .depend_in(deps[i])
                .depend_out(deps[i + 1])
                .nowait()
                .submit()?;
        }
        // host mid-pipeline task BETWEEN two FPGA chains — the
        // interleaving the old executor crashed on
        let mid = STAGE_ITERS;
        ctx.task("renormalize")
            .map(MapDir::ToFrom, "V")
            .depend_in(deps[mid])
            .depend_out(deps[mid + 1])
            .nowait()
            .submit()?;
        // second FPGA pipeline, also unbound
        for i in 0..STAGE_ITERS {
            ctx.target("do_diffusion2d")
                .device_any()
                .map(MapDir::ToFrom, "V")
                .depend_in(deps[mid + 1 + i])
                .depend_out(deps[mid + 2 + i])
                .nowait()
                .submit()?;
        }
        // host post-processing task
        ctx.task("postprocess")
            .map(MapDir::ToFrom, "V")
            .depend_in(deps[2 * STAGE_ITERS + 1])
            .depend_out(deps[2 * STAGE_ITERS + 2])
            .nowait()
            .submit()?;
        Ok(())
    })?;

    // the scheduler must have split the graph host/fpga/host/fpga/host
    println!("batch timeline (virtual seconds):");
    for (dev, rep) in &report.batches {
        println!(
            "  device {} [{:>2} tasks]  release {:.6}  finish {:.6}",
            dev.0, rep.tasks_run, rep.release_s, rep.finish_s
        );
    }
    anyhow::ensure!(
        report.batches.len() == 5,
        "expected 5 batches (host/fpga/host/fpga/host), got {}",
        report.batches.len()
    );
    // placement check: both unbound chains went to the 3-board ring —
    // its 2-pass schedule beats the single board's 6 passes even after
    // paying the extra ring crossings
    for (dev, rep) in &report.batches {
        if rep.virtual_time_s > 0.0 {
            anyhow::ensure!(
                *dev == big,
                "placer chose device {} for an FPGA chain; expected the \
                 3-board ring (device {})",
                dev.0,
                big.0
            );
        }
    }
    println!(
        "device(any) placed both FPGA chains on device {} (3-board ring); \
         device {} (single board) stayed idle",
        big.0, small.0
    );
    println!(
        "modelled makespan {:.6} s over {} tasks",
        report.virtual_time_s(),
        report.tasks
    );

    // verify against the all-software composition
    let mut expected = input.clone();
    for v in expected.data_mut() {
        *v *= 0.5;
    }
    let mut expected = kernel.iterate(&expected, STAGE_ITERS)?;
    for v in expected.data_mut() {
        *v *= 2.0;
    }
    let expected = kernel.iterate(&expected, STAGE_ITERS)?;
    let got = env.take("V")?;
    let diff = got.max_abs_diff(&expected);
    println!("heterogeneous interleaved pipeline vs software max|Δ| = {diff:.3e}");
    anyhow::ensure!(diff < 1e-4, "verification failed");
    println!("heterogeneous OK");
    Ok(())
}
