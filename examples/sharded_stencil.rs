//! Cluster-wide grid sharding over the VC709 fabric (DESIGN.md §11)
//! and communication-avoiding sharded schedules (§12).
//!
//! A 1536x256 stencil grid (393,216 cells) is strictly larger than the
//! demo deployment's per-board tile budget (200,000 cells): no single
//! board can hold it, and `ShardPlan::decompose` says so by name.  The
//! grid is instead row-sharded across 2, 4 and 6 single-board VC709
//! devices with one ghost row per shared boundary; every sweep round is
//! followed by per-boundary halo-exchange tasks that ride the ordinary
//! task graph and cross the inter-FPGA fabric as CRC'd MAC frames,
//! priced by the configured topology's hop counts.
//!
//! Demonstrated end to end, with the numbers written to
//! `results/shard_scaling.json` (uploaded by CI's shard-smoke job):
//!
//! * the sharded result is **bit-identical** to the unsharded host
//!   reference at every board count;
//! * the modelled makespan **improves monotonically** from 2 to 6
//!   boards (smaller tiles stream faster than the added halo traffic
//!   costs);
//! * **temporal halo blocking** (`block = B`, halo deepened to match)
//!   cuts the exchange count from `(K-1)·2(n-1)` to
//!   `(ceil(K/B)-1)·2(n-1)` and strictly improves the modelled
//!   makespan — same gathered bits;
//! * **interior/boundary splitting** overlaps interior compute with
//!   in-flight halo frames: the halo-blocked seconds
//!   (`report.halo.wait_s`) drop versus the unsplit schedule at the
//!   same block factor — same gathered bits;
//! * a directed **ring** fabric prices the same schedule strictly
//!   slower than a **crossbar** (reverse-direction halos walk n-1
//!   links), while the grids stay identical — topology is a
//!   timing-plane concept;
//! * the placement estimate equals the executed duration to 1e-12 for
//!   halo batches on **both** topologies — one DES prices and executes.
//!
//! ```sh
//! cargo run --release --example sharded_stencil   # or: make sharded
//! ```

use anyhow::{ensure, Result};

use omp_fpga::config::ClusterConfig;
use omp_fpga::hw::{FabricSlot, Topology};
use omp_fpga::omp::{
    BatchCtx, DataEnv, DepVar, DeviceId, DevicePlugin, FnRegistry, MapDir,
    OmpReport, OmpRuntime, Residency, ShardPlan, ShardSpec, ShardedGrid,
    Task, TaskFn, TaskGraph, TaskId,
};
use omp_fpga::plugin::{ExecBackend, Vc709Plugin};
use omp_fpga::stencil::{Grid, Kernel};

const KERNEL: Kernel = Kernel::Laplace2d;
const SHAPE: [usize; 2] = [1536, 256];
/// Synthetic per-board tile budget (cells) for this deployment: roomy
/// enough for half the grid plus ghosts, far too small for all of it.
const CAPACITY_CELLS: usize = 200_000;
const SWEEPS: usize = 4;
/// Board count the §12 blocking/splitting ablation runs on.
const ABLATION_BOARDS: usize = 6;

fn spec() -> ShardSpec {
    ShardSpec {
        halo: 1,
        block: 1,
        split: false,
        capacity_cells: Some(CAPACITY_CELLS),
    }
}

/// `nboards` single-board VC709 devices sharing one fabric topology.
fn build_runtime(topology: Topology, nboards: usize) -> Result<OmpRuntime> {
    let mut rt = OmpRuntime::new(2);
    let mut cfg = ClusterConfig::homogeneous(1, 2, KERNEL);
    cfg.topology = topology;
    for d in 0..nboards {
        let mut plugin = Vc709Plugin::new(&cfg, ExecBackend::Golden)?;
        plugin.fabric = FabricSlot::new(topology, nboards, d)?;
        rt.register_device(Box::new(plugin));
    }
    Ok(rt)
}

/// Shard, run, gather.  Returns the gathered grid and the full report
/// (makespan, halo counters, per-batch stats).
fn run_sharded(
    topology: Topology,
    nboards: usize,
    spec: &ShardSpec,
    global: &Grid,
) -> Result<(Grid, OmpReport)> {
    let mut rt = build_runtime(topology, nboards)?;
    let plan = ShardPlan::decompose("V", &SHAPE, nboards, spec)?;
    let devices: Vec<DeviceId> = (1..=nboards).map(DeviceId).collect();
    let sharded =
        ShardedGrid::install(&mut rt, plan, KERNEL, devices, SWEEPS)?;
    let (out, report) = sharded.run(&mut rt, global)?;
    let halo_wire: f64 = report
        .batches
        .iter()
        .filter_map(|(_, r)| r.stats.modules.get("halo-wire"))
        .map(|m| m.bytes)
        .sum();
    let priced: f64 = report
        .batches
        .iter()
        .filter_map(|(_, r)| r.stats.modules.get("halo-net"))
        .map(|m| m.bytes)
        .sum();
    ensure!(
        halo_wire == priced,
        "functional halo bytes {halo_wire} != DES-priced bytes {priced}"
    );
    ensure!(
        report.halo.bytes == halo_wire,
        "halo counter {} != wire bytes {halo_wire}",
        report.halo.bytes
    );
    Ok((out, report))
}

/// Placement estimate vs executed duration for one cross-fabric halo
/// batch — the plugin prices and executes through the same DES.
fn estimate_matches_duration(topology: Topology) -> Result<(f64, f64)> {
    let op = omp_fpga::omp::HaloOp {
        src: "T0".into(),
        dst: "T1".into(),
        src_row0: 6,
        dst_row0: 0,
        nrows: 1,
        row_cells: 256,
        src_slot: 1,
        dst_slot: 0,
    };
    let mut fns = FnRegistry::default();
    fns.register("halo_x", TaskFn::Halo(op));
    let mut graph = TaskGraph::new();
    let id = graph.add(Task {
        id: TaskId(0),
        base_name: "halo_x".into(),
        fn_name: "halo_x".into(),
        device: DeviceId(1).into(),
        maps: vec![(MapDir::ToFrom, "T1".into())],
        deps_in: vec![],
        deps_out: vec![DepVar(0)],
        nowait: true,
    });
    let cfg = ClusterConfig::homogeneous(1, 2, KERNEL);
    let mut plugin = Vc709Plugin::new(&cfg, ExecBackend::Golden)?;
    plugin.fabric = FabricSlot::new(topology, 4, 0)?;
    let mut env = DataEnv::new();
    env.insert("T0", Grid::random(&[8, 256], 1)?);
    env.insert("T1", Grid::random(&[8, 256], 2)?);
    let est = plugin
        .estimate_batch_s(
            &graph,
            &[id],
            &["halo_x".to_string()],
            &fns,
            &env,
            &Residency::default(),
        )
        .ok_or_else(|| anyhow::anyhow!("halo batch must be priced"))?;
    let rep = plugin.run_batch(&graph, &[id], &mut env, &fns, &BatchCtx::at(0.0))?;
    ensure!(
        (est - rep.virtual_time_s).abs() < 1e-12,
        "{topology:?}: estimate {est} != duration {}",
        rep.virtual_time_s
    );
    Ok((est, rep.virtual_time_s))
}

fn main() -> Result<()> {
    let global = Grid::random(&SHAPE, 2024)?;
    let grid_cells = global.cells();
    ensure!(
        grid_cells > CAPACITY_CELLS,
        "the demo grid must exceed one board's budget"
    );
    // no single board holds this grid — the decomposition says so
    let err = ShardPlan::decompose("V", &SHAPE, 1, &spec())
        .unwrap_err()
        .to_string();
    ensure!(err.contains("board holds"), "{err}");
    println!(
        "grid {}x{} = {} cells; board budget {} cells",
        SHAPE[0], SHAPE[1], grid_cells, CAPACITY_CELLS
    );
    println!("1 board : refused — {err}");

    let reference = KERNEL.iterate(&global, SWEEPS)?;
    let mut rows = Vec::new();
    let mut last = f64::INFINITY;
    for nboards in [2usize, 4, 6] {
        let (out, report) =
            run_sharded(Topology::Ring, nboards, &spec(), &global)?;
        let makespan = report.virtual_time_s();
        ensure!(
            out == reference,
            "{nboards}-board sharded run diverged from the host reference"
        );
        ensure!(
            makespan < last,
            "makespan must improve with boards: {makespan} !< {last}"
        );
        last = makespan;
        println!(
            "{nboards} boards: makespan {makespan:.6} s, {} exchanges, \
             halo wire {:.0} B, halo wait {:.6} s — bit-identical",
            report.halo.exchanges, report.halo.bytes, report.halo.wait_s
        );
        rows.push(format!(
            "    {{\"boards\": {nboards}, \"makespan_s\": {makespan}, \
             \"halo_exchanges\": {}, \"halo_wire_bytes\": {}, \
             \"halo_wait_s\": {}}}",
            report.halo.exchanges, report.halo.bytes, report.halo.wait_s
        ));
    }

    // §12 ablation on the 6-board ring: temporal blocking cuts the
    // exchange count by the predicted factor and strictly improves the
    // modelled makespan; splitting then drops the halo-blocked seconds
    // at the same block factor — every configuration bit-identical
    let n = ABLATION_BOARDS;
    let mut ablation_rows = Vec::new();
    let mut baseline: Option<OmpReport> = None;
    for (block, split) in [(1, false), (2, false), (2, true)] {
        let spec = ShardSpec {
            halo: block,
            block,
            split,
            capacity_cells: Some(CAPACITY_CELLS),
        };
        let (out, report) =
            run_sharded(Topology::Ring, n, &spec, &global)?;
        ensure!(
            out == reference,
            "block={block} split={split} diverged from the reference"
        );
        let predicted =
            (SWEEPS.div_ceil(block) - 1) * 2 * (n - 1);
        ensure!(
            report.halo.exchanges == predicted,
            "block={block}: {} exchanges, blocking predicts {predicted}",
            report.halo.exchanges
        );
        println!(
            "{n} boards, block={block}{}: makespan {:.6} s, \
             {} exchanges, halo wait {:.6} s — bit-identical",
            if split { ", split" } else { "" },
            report.virtual_time_s(),
            report.halo.exchanges,
            report.halo.wait_s
        );
        ablation_rows.push(format!(
            "    {{\"block\": {block}, \"split\": {split}, \
             \"makespan_s\": {}, \"halo_exchanges\": {}, \
             \"halo_wire_bytes\": {}, \"halo_wait_s\": {}}}",
            report.virtual_time_s(),
            report.halo.exchanges,
            report.halo.bytes,
            report.halo.wait_s
        ));
        match (block, split) {
            (1, false) => baseline = Some(report),
            (2, false) => {
                let base = baseline.as_ref().expect("baseline ran first");
                ensure!(
                    report.halo.exchanges < base.halo.exchanges,
                    "blocking must cut exchanges: {} !< {}",
                    report.halo.exchanges,
                    base.halo.exchanges
                );
                ensure!(
                    report.virtual_time_s() < base.virtual_time_s(),
                    "blocking must improve the makespan: {} !< {}",
                    report.virtual_time_s(),
                    base.virtual_time_s()
                );
                baseline = Some(report);
            }
            (2, true) => {
                // `baseline` now holds block=2 unsplit: same exchange
                // schedule, but interior compute no longer stalls on it
                let unsplit = baseline.as_ref().expect("unsplit ran");
                ensure!(
                    report.halo.exchanges == unsplit.halo.exchanges,
                    "splitting must not change the exchange schedule"
                );
                ensure!(
                    report.halo.wait_s < unsplit.halo.wait_s,
                    "splitting must drop the halo-blocked seconds: \
                     {} !< {}",
                    report.halo.wait_s,
                    unsplit.halo.wait_s
                );
            }
            _ => unreachable!(),
        }
    }

    // same schedule, different fabric: ring prices slower than crossbar
    let (g_ring, rep_ring) =
        run_sharded(Topology::Ring, 4, &spec(), &global)?;
    let (g_xbar, rep_xbar) =
        run_sharded(Topology::Crossbar, 4, &spec(), &global)?;
    let (m_ring, m_xbar) =
        (rep_ring.virtual_time_s(), rep_xbar.virtual_time_s());
    ensure!(g_ring == g_xbar, "topology must not touch numerics");
    ensure!(
        m_ring > m_xbar,
        "multi-hop ring halos must outprice the crossbar: \
         {m_ring} vs {m_xbar}"
    );
    println!(
        "4 boards: ring {m_ring:.6} s vs crossbar {m_xbar:.6} s \
         (same grids)"
    );

    // one DES prices and executes, whatever the fabric
    let (er, _) = estimate_matches_duration(Topology::Ring)?;
    let (ex, _) = estimate_matches_duration(Topology::Crossbar)?;
    println!(
        "halo estimate == duration: ring {er:.9} s, crossbar {ex:.9} s"
    );

    std::fs::create_dir_all("results")?;
    let json = format!(
        "{{\n  \"grid_cells\": {grid_cells},\n  \
         \"board_capacity_cells\": {CAPACITY_CELLS},\n  \
         \"sweeps\": {SWEEPS},\n  \"scaling\": [\n{}\n  ],\n  \
         \"blocking_ablation\": [\n{}\n  ],\n  \
         \"ring_makespan_s\": {m_ring},\n  \
         \"crossbar_makespan_s\": {m_xbar}\n}}\n",
        rows.join(",\n"),
        ablation_rows.join(",\n")
    );
    std::fs::write("results/shard_scaling.json", json)?;
    println!("wrote results/shard_scaling.json");
    Ok(())
}
