//! Compile-once / run-many stencil service: the `capture → compile →
//! execute` program API over the VC709 cluster.
//!
//! A serving workload replays the *same* parallel region for every
//! request, only the buffer contents change.  The one-shot `parallel`
//! path re-derives the task graph, the run condensation and the
//! `device(any)` placement per request; here the region is captured
//! into an `omp::Program` once, compiled once into an `Executable`
//! (condensation + placement + writeback planning), and replayed per
//! request with zero re-planning — same grids, same makespans, a
//! fraction of the host-side planning work.  (`parallel` itself gets
//! the same effect transparently through the runtime's plan cache;
//! holding the executable also skips the per-call tracing.)
//!
//! The plan is then persisted (`Executable::save`) and reloaded into a
//! fresh runtime (`OmpRuntime::load_executable`) — the warm start: a
//! new process serves requests with **zero** compiles, bit-identical
//! grids, after the loader revalidates epoch, device registry,
//! residency fingerprint and format version.
//!
//! ```sh
//! cargo run --release --example served_stencil   # or: make warm-start
//! ```

use anyhow::Result;

use omp_fpga::config::ClusterConfig;
use omp_fpga::omp::{DataEnv, DepVar, MapDir, OmpRuntime, SingleCtx};
use omp_fpga::plugin::{ExecBackend, Vc709Plugin};
use omp_fpga::stencil::{Grid, Kernel};

const REQUESTS: usize = 8;
const STEPS: usize = 4;

fn build_runtime(kernel: Kernel) -> Result<OmpRuntime> {
    let mut rt = OmpRuntime::new(2);
    rt.declare_hw_variant("do_step", "vc709", "hw_step", kernel);
    // two single-board clusters — the unbound chain is placed by the
    // scheduler's communication-aware cost model at compile time
    let cfg = ClusterConfig::homogeneous(1, 2, kernel);
    for _ in 0..2 {
        rt.register_device(Box::new(Vc709Plugin::new(
            &cfg,
            ExecBackend::Golden,
        )?));
    }
    Ok(rt)
}

/// The served region: one request = a 4-step unbound stencil chain.
fn submit_request(ctx: &mut SingleCtx, deps: &[DepVar]) -> Result<()> {
    for i in 0..STEPS {
        ctx.target("do_step")
            .device_any()
            .map(MapDir::ToFrom, "V")
            .depend_in(deps[i])
            .depend_out(deps[i + 1])
            .nowait()
            .submit()?;
    }
    Ok(())
}

fn main() -> Result<()> {
    let kernel = Kernel::Diffusion2d;
    let input = Grid::random(&[48, 32], 7)?;

    // -- baseline: one parallel region per request, no plan reuse ------
    let mut rt = build_runtime(kernel)?;
    rt.set_plan_cache(false); // the pre-compile-once behaviour
    let mut env = DataEnv::new();
    env.insert("V", input.clone());
    let mut t_baseline = Vec::new();
    for _ in 0..REQUESTS {
        let deps = rt.dep_vars(STEPS + 1);
        let report =
            rt.parallel(&mut env, |ctx| submit_request(ctx, &deps))?;
        t_baseline.push(report.virtual_time_s());
    }
    let g_baseline = env.take("V")?;
    println!(
        "parallel x{REQUESTS}  : {} plans built, {} placements computed",
        rt.plan_stats().plans_built,
        rt.plan_stats().placements_computed
    );
    let plans_baseline = rt.plan_stats().plans_built;

    // -- service: capture once, compile once, execute per request ------
    let mut rt = build_runtime(kernel)?;
    let mut env = DataEnv::new();
    env.insert("V", input.clone());
    let deps = rt.dep_vars(STEPS + 1);
    let program = rt.capture(&env, |ctx| submit_request(ctx, &deps))?;
    let exe = program.compile(&mut rt)?;
    println!(
        "compiled      : {} tasks over {} slot(s), {} batch(es), \
         modelled makespan {:.6} s",
        program.task_count(),
        program.slots().len(),
        exe.batch_count(),
        exe.makespan_s()
    );
    // persist the compiled plan NOW (pre-serving, while the residency
    // state it was priced against still holds) for the warm start below
    std::fs::create_dir_all("results")?;
    let plan_path = std::path::Path::new("results/served_stencil.plan.json");
    exe.save(&rt, plan_path)?;
    println!("saved         : {}", plan_path.display());
    let mut t_served = Vec::new();
    for _ in 0..REQUESTS {
        let report = exe.execute(&mut rt, &mut env)?;
        t_served.push(report.virtual_time_s());
    }
    let g_served = env.take("V")?;
    println!(
        "execute x{REQUESTS}   : {} plan built, {} placement computed, \
         {} executions",
        rt.plan_stats().plans_built,
        rt.plan_stats().placements_computed,
        rt.plan_stats().executions
    );

    // the reused plan is exact, not an approximation
    anyhow::ensure!(
        t_served == t_baseline,
        "per-request makespans diverged: {t_served:?} vs {t_baseline:?}"
    );
    anyhow::ensure!(g_served == g_baseline, "numerics must be bit-identical");
    anyhow::ensure!(
        rt.plan_stats().plans_built == 1 && plans_baseline == REQUESTS,
        "compile-once must do 1/N of the planning work"
    );
    println!(
        "served {REQUESTS} requests at {:.6} s/request with one compiled \
         plan (baseline built {plans_baseline}) — grids bit-identical",
        t_served[0]
    );

    // -- warm start: a fresh "process" loads the plan from disk --------
    // same registration sequence → same epoch and device registry; the
    // loader revalidates both (plus the residency fingerprint and the
    // format version) before it will replay anything
    let mut rt = build_runtime(kernel)?;
    let exe = rt.load_executable(plan_path)?;
    let mut env = DataEnv::new();
    env.insert("V", input.clone());
    let mut t_warm = Vec::new();
    for _ in 0..REQUESTS {
        let report = exe.execute(&mut rt, &mut env)?;
        t_warm.push(report.virtual_time_s());
    }
    let g_warm = env.take("V")?;
    anyhow::ensure!(
        rt.plan_stats().plans_built == 0,
        "a warm start must compile nothing"
    );
    anyhow::ensure!(t_warm == t_served, "warm-start makespans diverged");
    anyhow::ensure!(g_warm == g_served, "warm-start grids must be bit-identical");
    println!(
        "warm start    : loaded {} and served {REQUESTS} requests with \
         0 plans built — grids bit-identical",
        plan_path.display()
    );
    Ok(())
}
