//! Quickstart: the paper's Listing 3, in Rust, end to end.
//!
//! Builds a 16-task Laplace-2D pipeline over a small grid, offloads it to
//! a simulated 2-board VC709 cluster executing the AOT-compiled Pallas
//! artifacts through PJRT, and verifies the result against the software
//! (host OpenMP) version — the paper's verification flow.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{Context, Result};

use omp_fpga::config::ClusterConfig;
use omp_fpga::omp::{DataEnv, MapDir, OmpRuntime};
use omp_fpga::plugin::{ExecBackend, Vc709Plugin};
use omp_fpga::stencil::{Grid, Kernel};

const ITERS: usize = 16;

fn main() -> Result<()> {
    let kernel = Kernel::Laplace2d;
    let shape = [64usize, 48]; // matches the "small" AOT artifact

    // --- runtime setup (what the compiler + libomptarget do) ------------
    let mut rt = OmpRuntime::new(4);
    // software version of the function (Listing 3's do_laplace2d)
    rt.register_software("do_laplace2d", move |env| {
        let g = env.take("V")?;
        env.put("V", kernel.apply(&g)?);
        Ok(())
    });
    // #pragma omp declare variant (do_laplace2d) match(device=arch(vc709))
    rt.declare_hw_variant("do_laplace2d", "vc709", "hw_laplace2d", kernel);
    // the vc709 device plugin: 2 boards x 4 Laplace-2D IPs, PJRT backend
    let cfg = ClusterConfig::homogeneous(2, 4, kernel);
    let plugin = Vc709Plugin::new(&cfg, ExecBackend::Pjrt)
        .context("run `make artifacts` first")?;
    println!("device: {}", {
        use omp_fpga::omp::device::DevicePlugin;
        plugin.describe()
    });
    let fpga = rt.register_device(Box::new(plugin));
    rt.set_default_device(fpga); // the -fopenmp-targets=vc709 flag

    // --- the user program (Listing 3) -----------------------------------
    let input = Grid::random(&shape, 7)?;
    let mut env = DataEnv::new();
    env.insert("V", input.clone());
    let deps = rt.dep_vars(ITERS + 1);
    let report = rt.parallel(&mut env, |ctx| {
        for i in 0..ITERS {
            ctx.target("do_laplace2d")
                .map(MapDir::ToFrom, "V")
                .depend_in(deps[i])
                .depend_out(deps[i + 1])
                .nowait()
                .submit()?;
        }
        Ok(())
    })?;
    let result = env.take("V")?;

    // --- verification flow: the software version ------------------------
    let expected = kernel.iterate(&input, ITERS)?;
    let diff = result.max_abs_diff(&expected);
    println!(
        "{ITERS} pipelined tasks on {} FPGAs: modelled time {:.3} ms, \
         wall {:.1} ms",
        cfg.nfpgas(),
        report.virtual_time_s() * 1e3,
        report.wall_s * 1e3
    );
    println!("PJRT vs software max|Δ| = {diff:.3e}");
    anyhow::ensure!(diff < 1e-4, "verification failed");
    println!("quickstart OK");
    Ok(())
}
