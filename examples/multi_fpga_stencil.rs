//! End-to-end driver (DESIGN.md §4): the full Table-II Laplace-2D
//! workload — 4096x512 grid, 240 pipelined iterations — on the simulated
//! 6-board ring, with real numerics through the PJRT-compiled Pallas
//! artifacts, cross-checked against the pure-host software run.
//!
//! Also sweeps 1..=6 FPGAs and prints the Fig-6/Fig-7 rows for this
//! kernel, demonstrating the near-linear scaling claim on real numerics
//! (not just the timing model).
//!
//! ```sh
//! make artifacts && cargo run --release --example multi_fpga_stencil
//! # pass --golden to skip PJRT, --iterations N / --scale S to shrink
//! ```

use anyhow::Result;

use omp_fpga::exec::{run_host_reference, run_stencil_app, RunSpec};
use omp_fpga::plugin::ExecBackend;
use omp_fpga::stencil::workload::paper_workload;
use omp_fpga::stencil::Kernel;
use omp_fpga::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let mut w = paper_workload(Kernel::Laplace2d);
    if let Some(n) = args.usize_flag("iterations")? {
        w = w.with_iterations(n);
    }
    let mut backend = if args.has("golden") {
        ExecBackend::Golden
    } else {
        ExecBackend::Pjrt
    };
    if let Some(s) = args.usize_flag("scale")? {
        w = w.scaled(s);
        if backend == ExecBackend::Pjrt {
            // AOT artifacts are shape-static (like bitstreams); scaled
            // grids have no artifact, so fall back to the golden model
            eprintln!("note: --scale has no AOT artifact; using --golden");
            backend = ExecBackend::Golden;
        }
    }

    println!(
        "workload: {} {:?}, {} iterations, {} IPs/FPGA, backend {:?}",
        w.kernel.name(),
        w.shape,
        w.iterations,
        w.ips_per_fpga,
        backend
    );
    println!("computing host reference (software OpenMP path)...");
    let reference = run_host_reference(&w, 42)?;
    let ref_sum = reference.checksum();

    println!(
        "\n{:>5} {:>7} {:>12} {:>9} {:>9} {:>10}  numerics",
        "FPGAs", "passes", "virtual(s)", "speedup", "GFLOPS", "wall(s)"
    );
    let mut base = None;
    for f in 1..=6usize {
        let mut spec = RunSpec::new(w.clone(), f, backend);
        spec.keep_grid = true;
        let res = run_stencil_app(&spec)?;
        let b = *base.get_or_insert(res.virtual_time_s);
        let grid = res.grid.as_ref().unwrap();
        let diff = grid.max_abs_diff(&reference);
        let ok = diff < 2e-4;
        println!(
            "{f:>5} {:>7} {:>12.4} {:>9.2} {:>9.2} {:>10.2}  max|Δ|={diff:.1e} {}",
            res.passes,
            res.virtual_time_s,
            b / res.virtual_time_s,
            res.gflops,
            res.wall_s,
            if ok { "OK" } else { "FAIL" }
        );
        anyhow::ensure!(ok, "numerics diverged at {f} FPGAs");
        anyhow::ensure!(
            (grid.checksum().0 - ref_sum.0).abs()
                < 1e-3 * ref_sum.0.abs().max(1.0),
            "checksum drift"
        );
    }
    println!(
        "\nall FPGA counts produced identical numerics — the Multi-FPGA \
         pipeline is transparent, as the paper claims"
    );
    Ok(())
}
