//! Multi-tenant serving over the compile-once runtime (`omp::serve`,
//! DESIGN.md §10): four tenants with different shapes, weights and
//! traffic share two VC709 clusters — and one board dies mid-run.
//!
//! What this exercises, end to end:
//!
//! * **shape-keyed coalescing** — the two tenants sharing the `"B"`
//!   service fold onto one compiled `Executable`; every request after a
//!   shape's first replays with zero re-planning;
//! * **admission control** — the bursty tenant's queue bound rejects
//!   overload at the door, with per-tenant accounting;
//! * **weighted fair queueing** — the paying tenant (weight 4) gets a
//!   proportionally larger share of the boards while backlogged, and
//!   nobody starves;
//! * **residency-affine placement** — the hot tenant's working set is
//!   pinned device-resident, so its requests keep landing on its board
//!   with the H2D elided;
//! * **graceful degradation** — a board death mid-service recovers
//!   inside the victim request, the stale shared plans recompile with
//!   the failure named, and every admitted request still completes with
//!   grids **bit-identical** to a failure-free, compile-per-request
//!   baseline.
//!
//! ```sh
//! cargo run --release --example multi_tenant_serving   # or: make serving
//! ```

use anyhow::{ensure, Result};

use omp_fpga::config::ClusterConfig;
use omp_fpga::omp::{
    serve, DeviceId, FaultSchedule, OmpRuntime, ServeConfig, TenantSpec,
};
use omp_fpga::plugin::{ExecBackend, Vc709Plugin};
use omp_fpga::stencil::Kernel;

const KERNEL: Kernel = Kernel::Diffusion2d;
const SERVICES: [&str; 3] = ["A", "B", "C"];

fn build_runtime() -> Result<OmpRuntime> {
    let mut rt = OmpRuntime::new(2);
    // software fallback for whichever service buffer the task mapped
    rt.register_software("do_step", |env| {
        for name in SERVICES {
            if let Ok(g) = env.take(name) {
                env.put(name, KERNEL.apply(&g)?);
                return Ok(());
            }
        }
        anyhow::bail!("do_step: no known service buffer bound")
    });
    rt.declare_hw_variant("do_step", "vc709", "hw_step", KERNEL);
    // an asymmetric pair: placement prefers the 4-IP cluster — which is
    // exactly the board the fault schedule below kills
    for ips in [4, 1] {
        let cfg = ClusterConfig::homogeneous(1, ips, KERNEL);
        rt.register_device(Box::new(Vc709Plugin::new(
            &cfg,
            ExecBackend::Golden,
        )?));
    }
    Ok(rt)
}

fn fleet() -> Vec<TenantSpec> {
    vec![
        // paying tenant: heavy weight, device-resident working set
        TenantSpec::new("pro", "A", &[16, 12], 3)
            .weight(4.0)
            .requests(12)
            .mean_gap_s(1e-5)
            .resident(),
        // two free tenants coalescing onto one shared "B" plan
        TenantSpec::new("free-1", "B", &[12, 10], 2)
            .requests(10)
            .mean_gap_s(2e-5),
        TenantSpec::new("free-2", "B", &[12, 10], 2)
            .requests(10)
            .mean_gap_s(2e-5),
        // bursty batch tenant: everything at t=0 against a small queue
        TenantSpec::new("batch", "C", &[10, 8], 4)
            .requests(16)
            .queue_cap(6),
    ]
}

fn main() -> Result<()> {
    // -- the degraded run: coalesced serving through a board death -----
    let mut rt = build_runtime()?;
    rt.inject_faults(
        FaultSchedule::new().fail_after_batches(DeviceId(1), 4),
    )?;
    let cfg = ServeConfig::new(fleet()).seed(11);
    let out = serve(&mut rt, &cfg)?;
    let r = &out.report;
    println!("== multi-tenant serving (board 1 dies mid-run) ==");
    for line in r.summary_lines() {
        println!("{line}");
    }

    // conservation: rejection happens at the door, never mid-flight
    ensure!(r.generated == r.admitted + r.rejected, "conservation");
    ensure!(r.completed == r.admitted, "an admitted request was dropped");
    ensure!(
        r.rejected > 0,
        "the batch tenant's queue bound should reject overload"
    );
    ensure!(
        r.per_tenant["pro"].affine_device.is_some(),
        "the resident tenant must be pinned to a board"
    );
    // the death was survived, not avoided
    ensure!(rt.is_dead(DeviceId(1)), "the fault schedule fired");
    ensure!(
        r.recovered_requests >= 1,
        "a victim request must recover in-flight"
    );
    ensure!(
        r.stale_recompiles.iter().any(|s| s.contains("device_failed")),
        "stale plans must be evicted with the failure named: {:?}",
        r.stale_recompiles
    );
    ensure!(
        r.plan_hits > 0,
        "coalescing must replay shared plans: {r:?}"
    );

    // -- the referee: failure-free, compile-per-request baseline -------
    let mut rt_ref = build_runtime()?;
    let base = serve(&mut rt_ref, &cfg.clone().coalesce(false))?;
    ensure!(
        out.grids == base.grids,
        "board death + coalescing must be numerically invisible"
    );
    ensure!(
        base.report.plan_misses == base.report.completed,
        "the baseline compiles per request"
    );
    println!(
        "\nsurvived a board death mid-run: {} requests completed \
         ({} recovered in-flight, {} plans evicted by name), grids \
         bit-identical to the failure-free cold baseline",
        r.completed,
        r.recovered_requests,
        r.stale_recompiles.len()
    );
    Ok(())
}
